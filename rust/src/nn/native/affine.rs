//! Native affine (dense / fully-connected) kernel, forward + VJP.
//!
//! `y = x Wᵀ + b` with `x[b, fi]`, `W[fo, fi]`, `b[fo]` — the sequential
//! layer function inside the §4 distributed affine algorithm. All three
//! products (forward, `δx`, `δW`) are routed through the shared blocked
//! GEMM core in [`super::gemm`] and hence through its persistent worker
//! pool and dispatched microkernels; the previous ad-hoc cache-blocked
//! loops survive as [`affine_forward_naive`] /
//! [`affine_backward_naive`], the references the parity tests and benches
//! compare against. The AOT XLA/Pallas executable still replaces the
//! whole kernel on the LeNet hot path.

use super::gemm::gemm;
use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Cache block edge for the reference blocked loops.
const BLOCK: usize = 64;

fn affine_dims<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
) -> Result<(usize, usize, usize)> {
    if x.rank() != 2 || w.rank() != 2 {
        return Err(Error::Shape("affine expects rank-2 x and w".into()));
    }
    let (b, fi) = (x.shape()[0], x.shape()[1]);
    let (fo, fi2) = (w.shape()[0], w.shape()[1]);
    if fi != fi2 {
        return Err(Error::Shape(format!("affine: features {fi} vs weight {fi2}")));
    }
    if let Some(bias) = bias {
        if bias.shape() != [fo] {
            return Err(Error::Shape(format!(
                "affine: bias {:?} vs fo {fo}",
                bias.shape()
            )));
        }
    }
    Ok((b, fi, fo))
}

/// Forward affine: `y[b,fo] = x[b,fi] @ W[fo,fi]^T + bias[fo]` — one GEMM
/// with B transposed (`W` is consumed in its stored layout).
pub fn affine_forward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
) -> Result<Tensor<T>> {
    let (b, fi, fo) = affine_dims(x, w, bias)?;
    let mut y = Tensor::zeros(&[b, fo]);
    gemm(b, fo, fi, x.data(), false, w.data(), true, y.data_mut())?;
    if let Some(bias) = bias {
        let bd = bias.data();
        let yd = y.data_mut();
        for i in 0..b {
            let yrow = &mut yd[i * fo..(i + 1) * fo];
            for (v, &bv) in yrow.iter_mut().zip(bd.iter()) {
                *v += bv;
            }
        }
    }
    Ok(y)
}

/// Affine VJP: `(dx, dw, db)` from `dy[b,fo]` — two GEMMs and a column
/// reduction.
pub fn affine_backward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
    let (b, fi, fo) = affine_dims(x, w, None)?;
    crate::tensor::check_same(dy.shape(), &[b, fo], "affine_backward dy")?;
    let dyd = dy.data();
    // dx[b,fi] = dy[b,fo] @ W[fo,fi]
    let mut dx = Tensor::zeros(&[b, fi]);
    gemm(b, fi, fo, dyd, false, w.data(), false, dx.data_mut())?;
    // dw[fo,fi] = dy[b,fo]^T @ x[b,fi]
    let mut dw = Tensor::zeros(&[fo, fi]);
    gemm(fo, fi, b, dyd, true, x.data(), false, dw.data_mut())?;
    // db[o] = sum_i dy[i,o]
    let mut db = Tensor::zeros(&[fo]);
    {
        let dbd = db.data_mut();
        for i in 0..b {
            let dyrow = &dyd[i * fo..(i + 1) * fo];
            for (acc, &g) in dbd.iter_mut().zip(dyrow.iter()) {
                *acc += g;
            }
        }
    }
    Ok((dx, dw, db))
}

/// Reference forward affine — the original ad-hoc blocked loops, retained
/// for the parity tests and the kernel-speedup benches.
pub fn affine_forward_naive<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
) -> Result<Tensor<T>> {
    let (b, fi, fo) = affine_dims(x, w, bias)?;
    let mut y = Tensor::zeros(&[b, fo]);
    let xd = x.data();
    let wd = w.data();
    let yd = y.data_mut();
    // y[i,o] = sum_k x[i,k] * w[o,k]  (blocked over k and o)
    for k0 in (0..fi).step_by(BLOCK) {
        let k1 = (k0 + BLOCK).min(fi);
        for o0 in (0..fo).step_by(BLOCK) {
            let o1 = (o0 + BLOCK).min(fo);
            for i in 0..b {
                let xrow = &xd[i * fi..(i + 1) * fi];
                let yrow = &mut yd[i * fo..(i + 1) * fo];
                for o in o0..o1 {
                    let wrow = &wd[o * fi..(o + 1) * fi];
                    let mut acc = T::ZERO;
                    for k in k0..k1 {
                        acc += xrow[k] * wrow[k];
                    }
                    yrow[o] += acc;
                }
            }
        }
    }
    if let Some(bias) = bias {
        let bd = bias.data();
        for i in 0..b {
            for o in 0..fo {
                yd[i * fo + o] += bd[o];
            }
        }
    }
    Ok(y)
}

/// Reference affine VJP — the original loops, retained for the parity
/// tests and the kernel-speedup benches.
pub fn affine_backward_naive<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
    let (b, fi, fo) = affine_dims(x, w, None)?;
    crate::tensor::check_same(dy.shape(), &[b, fo], "affine_backward dy")?;
    let xd = x.data();
    let wd = w.data();
    let dyd = dy.data();
    // dx[i,k] = sum_o dy[i,o] * w[o,k]
    let mut dx = Tensor::zeros(&[b, fi]);
    {
        let dxd = dx.data_mut();
        for i in 0..b {
            let dyrow = &dyd[i * fo..(i + 1) * fo];
            let dxrow = &mut dxd[i * fi..(i + 1) * fi];
            for o in 0..fo {
                let g = dyrow[o];
                if g == T::ZERO {
                    continue;
                }
                let wrow = &wd[o * fi..(o + 1) * fi];
                for k in 0..fi {
                    dxrow[k] += g * wrow[k];
                }
            }
        }
    }
    // dw[o,k] = sum_i dy[i,o] * x[i,k]
    let mut dw = Tensor::zeros(&[fo, fi]);
    {
        let dwd = dw.data_mut();
        for i in 0..b {
            let dyrow = &dyd[i * fo..(i + 1) * fo];
            let xrow = &xd[i * fi..(i + 1) * fi];
            for o in 0..fo {
                let g = dyrow[o];
                if g == T::ZERO {
                    continue;
                }
                let dwrow = &mut dwd[o * fi..(o + 1) * fi];
                for k in 0..fi {
                    dwrow[k] += g * xrow[k];
                }
            }
        }
    }
    // db[o] = sum_i dy[i,o]
    let mut db = Tensor::zeros(&[fo]);
    {
        let dbd = db.data_mut();
        for i in 0..b {
            for o in 0..fo {
                dbd[o] += dyd[i * fo + o];
            }
        }
    }
    Ok((dx, dw, db))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;
    use crate::util::rng::SplitMix64;

    fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
        Tensor::from_vec(
            shape,
            (0..crate::tensor::numel(shape))
                .map(|_| rng.next_f64() - 0.5)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn known_values() {
        let x = Tensor::<f64>::from_vec(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::<f64>::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let b = Tensor::<f64>::from_vec(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        let y = affine_forward(&x, &w, Some(&b)).unwrap();
        assert_eq!(y.data(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn matches_naive_matmul() {
        let mut rng = SplitMix64::new(3);
        let x = rand_t(&[5, 130], &mut rng); // exceeds one cache block
        let w = rand_t(&[70, 130], &mut rng);
        let y = affine_forward(&x, &w, None).unwrap();
        let wt = crate::tensor::ops::transpose2(&w).unwrap();
        let naive = crate::tensor::ops::matmul(&x, &wt).unwrap();
        assert!(y.allclose(&naive, 1e-10, 1e-10));
    }

    #[test]
    fn gemm_path_matches_naive_reference() {
        let mut rng = SplitMix64::new(8);
        let x = rand_t(&[9, 137], &mut rng);
        let w = rand_t(&[71, 137], &mut rng);
        let bias = rand_t(&[71], &mut rng);
        let y = affine_forward(&x, &w, Some(&bias)).unwrap();
        let y_ref = affine_forward_naive(&x, &w, Some(&bias)).unwrap();
        assert!(y.allclose(&y_ref, 1e-11, 1e-11));
        let dy = rand_t(&[9, 71], &mut rng);
        let (dx, dw, db) = affine_backward(&x, &w, &dy).unwrap();
        let (dx_r, dw_r, db_r) = affine_backward_naive(&x, &w, &dy).unwrap();
        assert!(dx.allclose(&dx_r, 1e-11, 1e-11));
        assert!(dw.allclose(&dw_r, 1e-11, 1e-11));
        assert!(db.allclose(&db_r, 1e-11, 1e-11));
    }

    #[test]
    fn vjp_finite_diff() {
        let mut rng = SplitMix64::new(4);
        let x = rand_t(&[4, 7], &mut rng);
        let w = rand_t(&[5, 7], &mut rng);
        let dy = rand_t(&[4, 5], &mut rng);
        let (dx, dw, db) = affine_backward(&x, &w, &dy).unwrap();
        check_vjp(&x, &dx, &dy, |xp| affine_forward(xp, &w, None).unwrap(), 1e-6, 1e-5);
        check_vjp(&w, &dw, &dy, |wp| affine_forward(&x, wp, None).unwrap(), 1e-6, 1e-5);
        let bias = rand_t(&[5], &mut rng);
        check_vjp(
            &bias,
            &db,
            &dy,
            |bp| affine_forward(&x, &w, Some(bp)).unwrap(),
            1e-6,
            1e-5,
        );
    }

    #[test]
    fn shape_errors() {
        let x = Tensor::<f64>::zeros(&[2, 3]);
        let w = Tensor::<f64>::zeros(&[4, 5]);
        assert!(affine_forward(&x, &w, None).is_err());
    }
}
