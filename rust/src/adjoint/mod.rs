//! Adjoint (coherence) testing — Eq. (13) of the paper.
//!
//! Numerical-gradient validation is impractical in parallel environments,
//! but every data-movement operation is **linear**, so the paper validates
//! implementations through the adjoint relationship ⟨Fx, y⟩ = ⟨x, F*y⟩:
//! an implementation of F* is *coherent* with F if
//!
//! ```text
//!   |⟨Fx, y⟩ − ⟨x, F*y⟩|
//!   ─────────────────────────────────  <  ε
//!   max(‖Fx‖·‖y‖, ‖x‖·‖F*y‖)
//! ```
//!
//! [`DistLinearOp`] is the interface every primitive in
//! [`crate::primitives`] implements: a forward map and a hand-derived
//! adjoint over *distributed* vectors (each world rank holds an optional
//! local shard). [`adjoint_residual`] runs the test across a live
//! [`crate::comm::Cluster`], computing the global inner products from
//! per-rank partials exactly as a production MPI implementation would.
//!
//! Eq. (13) has a *static* shadow: if F and F* are coherent, F*'s
//! message schedule must be F's transposed (every forward edge
//! `src → dst` answered by an adjoint edge `dst → src` of equal
//! volume). [`crate::analysis`] checks that structural half of the
//! relationship without executing any kernel math, complementing the
//! numerical residual this module computes on a live cluster.

use crate::comm::{Cluster, Comm};
use crate::error::Result;
use crate::tensor::{Scalar, Tensor};
use crate::util::rng::SplitMix64;

/// A linear operator between distributed tensor spaces.
///
/// Both the domain and codomain are "distributed vectors": each world rank
/// holds `Option<Tensor<T>>` — `None` when the rank does not participate in
/// that space (e.g. only the root holds the domain of a broadcast).
pub trait DistLinearOp<T: Scalar>: Sync {
    /// Local shard shape of the domain at `rank` (`None` = not present).
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>>;

    /// Local shard shape of the codomain at `rank`.
    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>>;

    /// Apply F to the local shard (SPMD: every rank calls this
    /// collectively).
    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>>;

    /// Apply the hand-derived adjoint F* (collective).
    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>>;

    /// Diagnostic name.
    fn name(&self) -> String;
}

/// Partial sums a rank contributes to the Eq. (13) residual.
#[derive(Debug, Default, Clone, Copy)]
struct Partials {
    fx_dot_y: f64,
    x_dot_fsy: f64,
    fx_sq: f64,
    y_sq: f64,
    x_sq: f64,
    fsy_sq: f64,
}

fn sq_norm<T: Scalar>(t: &Option<Tensor<T>>) -> f64 {
    t.as_ref().map(|t| t.norm().powi(2)).unwrap_or(0.0)
}

fn dot<T: Scalar>(a: &Option<Tensor<T>>, b: &Option<Tensor<T>>) -> Result<f64> {
    match (a, b) {
        (Some(a), Some(b)) => a.inner(b),
        (None, None) => Ok(0.0),
        _ => Err(crate::error::Error::Primitive(
            "inner product between mismatched shard presence".into(),
        )),
    }
}

/// Draw a random local shard for `shape` (uniform in [-0.5, 0.5)).
pub fn random_shard<T: Scalar>(
    shape: &Option<Vec<usize>>,
    rng: &mut SplitMix64,
) -> Option<Tensor<T>> {
    shape.as_ref().map(|s| {
        Tensor::from_vec(
            s,
            (0..crate::tensor::numel(s))
                .map(|_| T::from_f64(rng.next_f64() - 0.5))
                .collect(),
        )
        .expect("random shard")
    })
}

/// Run the Eq. (13) adjoint test for `op` on a fresh `world`-rank cluster
/// with deterministic random data, returning the relative residual.
///
/// In exact arithmetic the residual is zero; a coherent implementation in
/// f64 should sit at ~1e-15, and anything above `1e-12` indicates a wrong
/// adjoint (missing add, unreversed order, dropped clear, ...).
pub fn adjoint_residual<T: Scalar>(
    world: usize,
    op: &dyn DistLinearOp<T>,
    seed: u64,
) -> Result<f64> {
    adjoint_residual_under(world, op, seed, None)
}

/// [`adjoint_residual`] with a deterministic [`FaultPlan`] installed on
/// every endpoint before the collective runs (`None` = fault-free).
///
/// Because the engine resequences, deduplicates, and retransmits below
/// the primitive layer, a plan of delays/duplicates/reorders/drops must
/// leave the residual **bitwise identical** to the fault-free run — the
/// chaos sweeps assert exactly that.
pub fn adjoint_residual_under<T: Scalar>(
    world: usize,
    op: &dyn DistLinearOp<T>,
    seed: u64,
    plan: Option<&crate::comm::faults::FaultPlan>,
) -> Result<f64> {
    let partials = Cluster::run(world, |comm| {
        if let Some(p) = plan {
            comm.set_fault_plan(Some(p.clone()));
        }
        rank_partials(comm, op, seed)
    })?;
    Ok(residual_from(&partials))
}

/// One rank's contribution to the Eq. (13) inner products, with the
/// rank-deterministic data every harness variant draws identically.
fn rank_partials<T: Scalar>(
    comm: &mut Comm,
    op: &dyn DistLinearOp<T>,
    seed: u64,
) -> Result<Partials> {
    let rank = comm.rank();
    let mut rng = SplitMix64::new(seed ^ (rank as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let x = random_shard::<T>(&op.domain_shape(rank), &mut rng);
    let y = random_shard::<T>(&op.codomain_shape(rank), &mut rng);
    let fx = op.forward(comm, x.clone())?;
    let fsy = op.adjoint(comm, y.clone())?;
    Ok(Partials {
        fx_dot_y: dot(&fx, &y)?,
        x_dot_fsy: dot(&x, &fsy)?,
        fx_sq: sq_norm(&fx),
        y_sq: sq_norm(&y),
        x_sq: sq_norm(&x),
        fsy_sq: sq_norm(&fsy),
    })
}

/// Reduce per-rank partials — in rank order, so every harness variant
/// (in-process cluster, multi-process gather) accumulates in the same
/// floating-point order and the residual is bitwise reproducible.
fn residual_from(partials: &[Partials]) -> f64 {
    let mut tot = Partials::default();
    for p in partials {
        tot.fx_dot_y += p.fx_dot_y;
        tot.x_dot_fsy += p.x_dot_fsy;
        tot.fx_sq += p.fx_sq;
        tot.y_sq += p.y_sq;
        tot.x_sq += p.x_sq;
        tot.fsy_sq += p.fsy_sq;
    }
    let denom = (tot.fx_sq.sqrt() * tot.y_sq.sqrt()).max(tot.x_sq.sqrt() * tot.fsy_sq.sqrt());
    if denom == 0.0 {
        return 0.0;
    }
    (tot.fx_dot_y - tot.x_dot_fsy).abs() / denom
}

/// Tag pair (gather, result) reserved for [`adjoint_residual_on`]'s
/// reduction traffic — far above the tags any primitive under test uses.
const ADJOINT_GATHER_TAG: u64 = 0xAD70_0000_0000_0000;
const ADJOINT_RESULT_TAG: u64 = 0xAD70_0000_0000_0001;

/// Run the Eq. (13) adjoint test for `op` on an **already-connected**
/// cluster — every member calls this collectively and every member gets
/// the residual back. This is how a multi-*process* cluster (whose ranks
/// cannot return values to a shared parent the way
/// [`adjoint_residual`]'s in-process launcher can) runs the same sweep:
/// per-rank partials are gathered to rank 0 in rank order, reduced in
/// exactly the floating-point order [`adjoint_residual`] uses, and the
/// residual broadcast back — so the two harnesses agree bitwise.
pub fn adjoint_residual_on<T: Scalar>(
    comm: &mut Comm,
    op: &dyn DistLinearOp<T>,
    seed: u64,
) -> Result<f64> {
    let p = rank_partials(comm, op, seed)?;
    if comm.rank() == 0 {
        let mut all = Vec::with_capacity(comm.size());
        all.push(p);
        for src in 1..comm.size() {
            let v = comm.recv_vec::<f64>(src, ADJOINT_GATHER_TAG)?;
            if v.len() != 6 {
                return Err(crate::error::Error::Comm(format!(
                    "adjoint partials from rank {src}: got {} values, expected 6",
                    v.len()
                )));
            }
            all.push(Partials {
                fx_dot_y: v[0],
                x_dot_fsy: v[1],
                fx_sq: v[2],
                y_sq: v[3],
                x_sq: v[4],
                fsy_sq: v[5],
            });
        }
        let r = residual_from(&all);
        for dst in 1..comm.size() {
            comm.send_slice::<f64>(dst, ADJOINT_RESULT_TAG, &[r])?;
        }
        Ok(r)
    } else {
        let mine = [p.fx_dot_y, p.x_dot_fsy, p.fx_sq, p.y_sq, p.x_sq, p.fsy_sq];
        comm.send_slice::<f64>(0, ADJOINT_GATHER_TAG, &mine)?;
        Ok(comm.recv_vec::<f64>(0, ADJOINT_RESULT_TAG)?[0])
    }
}

/// Assert coherence with the default f64 threshold used throughout the
/// test-suite.
pub fn assert_coherent<T: Scalar>(world: usize, op: &dyn DistLinearOp<T>, seed: u64) {
    let r = adjoint_residual(world, op, seed).unwrap_or_else(|e| {
        panic!("adjoint test for {} failed to run: {e}", op.name());
    });
    assert!(
        r < 1e-12,
        "operator {} fails the Eq. (13) adjoint test: residual {r:.3e}",
        op.name()
    );
}

/// Additionally verify F is *linear* by spot-checking
/// F(αx + βx') = αFx + βFx' on random data — catches accidental affine
/// terms that the adjoint test alone can miss when they cancel.
pub fn linearity_residual<T: Scalar>(
    world: usize,
    op: &dyn DistLinearOp<T>,
    seed: u64,
) -> Result<f64> {
    let (alpha, beta) = (0.75, -1.25);
    let partials = Cluster::run(world, |comm| {
        let rank = comm.rank();
        let mut rng = SplitMix64::new(seed ^ 0xABCDEF ^ ((rank as u64) << 17));
        let x1 = random_shard::<T>(&op.domain_shape(rank), &mut rng);
        let x2 = random_shard::<T>(&op.domain_shape(rank), &mut rng);
        let combo = match (&x1, &x2) {
            (Some(a), Some(b)) => {
                let mut c = a.scale(T::from_f64(alpha));
                c.axpy(T::from_f64(beta), b)?;
                Some(c)
            }
            (None, None) => None,
            _ => unreachable!("domain presence is rank-deterministic"),
        };
        let f_combo = op.forward(comm, combo)?;
        let f1 = op.forward(comm, x1)?;
        let f2 = op.forward(comm, x2)?;
        let diff = match (f_combo, f1, f2) {
            (Some(fc), Some(f1), Some(f2)) => {
                let mut expect = f1.scale(T::from_f64(alpha));
                expect.axpy(T::from_f64(beta), &f2)?;
                fc.max_abs_diff(&expect)?
            }
            (None, None, None) => 0.0,
            _ => {
                return Err(crate::error::Error::Primitive(
                    "codomain presence changed between calls".into(),
                ))
            }
        };
        Ok(diff)
    })?;
    Ok(partials.into_iter().fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Comm;

    /// Identity on every rank — sanity-checks the harness itself.
    struct Identity {
        shape: Vec<usize>,
    }

    impl DistLinearOp<f64> for Identity {
        fn domain_shape(&self, _rank: usize) -> Option<Vec<usize>> {
            Some(self.shape.clone())
        }
        fn codomain_shape(&self, _rank: usize) -> Option<Vec<usize>> {
            Some(self.shape.clone())
        }
        fn forward(&self, _c: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
            Ok(x)
        }
        fn adjoint(&self, _c: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
            Ok(y)
        }
        fn name(&self) -> String {
            "I".into()
        }
    }

    /// Deliberately wrong adjoint (scales by 2 instead of 3) — the harness
    /// must reject it.
    struct BrokenScale;

    impl DistLinearOp<f64> for BrokenScale {
        fn domain_shape(&self, _rank: usize) -> Option<Vec<usize>> {
            Some(vec![8])
        }
        fn codomain_shape(&self, _rank: usize) -> Option<Vec<usize>> {
            Some(vec![8])
        }
        fn forward(&self, _c: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
            Ok(x.map(|t| t.scale(3.0)))
        }
        fn adjoint(&self, _c: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
            Ok(y.map(|t| t.scale(2.0)))
        }
        fn name(&self) -> String {
            "broken".into()
        }
    }

    #[test]
    fn identity_is_coherent() {
        let op = Identity { shape: vec![4, 3] };
        for world in [1, 2, 4] {
            assert_coherent(world, &op, 1);
        }
    }

    #[test]
    fn wrong_adjoint_detected() {
        let r = adjoint_residual(2, &BrokenScale, 7).unwrap();
        // residual is O(⟨x,y⟩/3‖x‖‖y‖) for random x,y — far above the
        // 1e-12 coherence threshold even when x, y are nearly orthogonal
        assert!(r > 1e-6, "broken adjoint slipped through: residual {r}");
    }

    #[test]
    fn residual_on_matches_parent_side_reduce_bitwise() {
        let op = Identity { shape: vec![4, 3] };
        let parent = adjoint_residual(3, &op, 42).unwrap();
        let gathered = Cluster::run(3, |comm| adjoint_residual_on(comm, &op, 42)).unwrap();
        for r in gathered {
            assert_eq!(r.to_bits(), parent.to_bits());
        }
    }

    #[test]
    fn identity_is_linear() {
        let op = Identity { shape: vec![5] };
        let r = linearity_residual(3, &op, 3).unwrap();
        assert!(r < 1e-12);
    }
}
