//! PJRT runtime: load and execute the AOT-compiled XLA/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers the L2 JAX functions (whose GEMM
//! hot-spots are the L1 Pallas kernel) to **HLO text** — the interchange
//! format this image's xla_extension 0.5.1 accepts (jax ≥ 0.5 serialized
//! protos carry 64-bit instruction ids it rejects; the text parser
//! reassigns ids). This module:
//!
//! * parses `artifacts/manifest.json` (hand-rolled JSON substrate);
//! * compiles each module once, lazily, on a dedicated **service thread**
//!   that owns the `PjRtClient` (the xla crate's handles are not `Send`,
//!   while [`crate::nn::LocalKernels`] must be `Send + Sync` — jobs are
//!   proxied over a channel, replies returned per call);
//! * exposes [`PjrtKernels`], a [`LocalKernels`] backend that dispatches
//!   conv/affine to artifacts when present and falls back to the native
//!   kernels otherwise (pooling and activations are always native — they
//!   are memory-bound and not the paper's hot-spot).

//! Without the `pjrt` cargo feature (the default — the `xla` crate is not
//! in the baseline dependency set), the manifest/naming machinery still
//! compiles and [`PjrtRuntime::new`] returns a descriptive error, so every
//! caller falls back to the native kernels at runtime.

use crate::error::{Error, Result};
use crate::nn::kernels::{LocalKernels, NativeKernels};
use crate::nn::native::{Conv2dSpec, Pool2dSpec};
use crate::tensor::Tensor;
use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use std::collections::HashSet;
use std::collections::HashMap;
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::mpsc::{channel, Sender};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// One artifact in the manifest.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Artifact name (encodes the op and its shapes).
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Expected input shapes.
    pub inputs: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub num_outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Entries by name.
    pub entries: HashMap<String, ManifestEntry>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = PathBuf::from(dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let mut entries = HashMap::new();
        for e in j.get("entries")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let file = e.get("file")?.as_str()?.to_string();
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|s| {
                    s.as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let num_outputs = e.get("num_outputs")?.as_usize()?;
            entries.insert(
                name.clone(),
                ManifestEntry {
                    name,
                    file,
                    inputs,
                    num_outputs,
                },
            );
        }
        Ok(Manifest { entries, dir })
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ManifestEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(feature = "pjrt")]
enum Job {
    Run {
        name: String,
        inputs: Vec<Tensor<f32>>,
        reply: Sender<Result<Vec<Tensor<f32>>>>,
    },
    Shutdown,
}

/// Handle to the PJRT service thread.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    manifest: Manifest,
    jobs: Mutex<Sender<Job>>,
    /// Names known to the manifest (fast membership checks without
    /// bouncing through the service thread).
    available: HashSet<String>,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Start the runtime for an artifacts directory.
    pub fn new(dir: &str) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(dir)?;
        let available: HashSet<String> = manifest.entries.keys().cloned().collect();
        let (tx, rx) = channel::<Job>();
        let thread_manifest = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                // The client and executables live only on this thread.
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        // Fail every job with the construction error.
                        while let Ok(job) = rx.recv() {
                            match job {
                                Job::Run { reply, .. } => {
                                    let _ = reply.send(Err(Error::Runtime(format!(
                                        "PJRT client failed to start: {e}"
                                    ))));
                                }
                                Job::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                let mut compiled: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Run {
                            name,
                            inputs,
                            reply,
                        } => {
                            let result =
                                run_job(&client, &thread_manifest, &mut compiled, &name, inputs);
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("cannot spawn pjrt service: {e}")))?;
        Ok(PjrtRuntime {
            manifest,
            jobs: Mutex::new(tx),
            available,
        })
    }

    /// Is an artifact available?
    pub fn has(&self, name: &str) -> bool {
        self.available.contains(name)
    }

    /// Names of all artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact.
    pub fn run(&self, name: &str, inputs: Vec<Tensor<f32>>) -> Result<Vec<Tensor<f32>>> {
        let (reply_tx, reply_rx) = channel();
        self.jobs
            .lock()
            .map_err(|_| Error::Runtime("pjrt job queue poisoned".into()))?
            .send(Job::Run {
                name: name.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| Error::Runtime("pjrt service thread is gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt service dropped the reply".into()))?
    }
}

#[cfg(feature = "pjrt")]
impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        if let Ok(tx) = self.jobs.lock() {
            let _ = tx.send(Job::Shutdown);
        }
    }
}

/// Stub runtime for builds without the `pjrt` feature: construction fails
/// with a descriptive error, so [`PjrtKernels::load`] surfaces the missing
/// capability instead of silently degrading.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: this build carries no XLA runtime.
    pub fn new(_dir: &str) -> Result<PjrtRuntime> {
        Err(Error::Runtime(
            "built without the `pjrt` feature: the XLA/PJRT runtime is unavailable; \
             use the native backend"
                .into(),
        ))
    }

    /// No artifacts are ever available in a stub build.
    pub fn has(&self, _name: &str) -> bool {
        false
    }

    /// No artifacts are ever available in a stub build.
    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Unreachable in practice (`new` never succeeds); kept for API parity.
    pub fn run(&self, name: &str, _inputs: Vec<Tensor<f32>>) -> Result<Vec<Tensor<f32>>> {
        Err(Error::Runtime(format!(
            "artifact '{name}' cannot run: built without the `pjrt` feature"
        )))
    }
}

#[cfg(feature = "pjrt")]
fn run_job(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    compiled: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: Vec<Tensor<f32>>,
) -> Result<Vec<Tensor<f32>>> {
    let entry = manifest
        .entries
        .get(name)
        .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))?;
    if inputs.len() != entry.inputs.len() {
        return Err(Error::Runtime(format!(
            "artifact '{name}': {} inputs given, {} expected",
            inputs.len(),
            entry.inputs.len()
        )));
    }
    for (i, (t, exp)) in inputs.iter().zip(entry.inputs.iter()).enumerate() {
        if t.shape() != &exp[..] {
            return Err(Error::Runtime(format!(
                "artifact '{name}': input {i} shape {:?} != manifest {:?}",
                t.shape(),
                exp
            )));
        }
    }
    if !compiled.contains_key(name) {
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        compiled.insert(name.to_string(), client.compile(&comp)?);
    }
    let exe = &compiled[name];
    let literals: Vec<xla::Literal> = inputs
        .into_iter()
        .map(|t| {
            let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(t.data())
                .reshape(&dims)
                .map_err(Error::from)
        })
        .collect::<Result<Vec<_>>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True.
    let parts = result.to_tuple()?;
    if parts.len() != entry.num_outputs {
        return Err(Error::Runtime(format!(
            "artifact '{name}': {} outputs, manifest says {}",
            parts.len(),
            entry.num_outputs
        )));
    }
    parts
        .into_iter()
        .map(|lit| {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            Tensor::from_vec(&dims, data)
        })
        .collect()
}

/// Artifact-name builders — must match `python/compile/aot.py`.
pub mod names {
    /// Conv forward artifact name.
    pub fn conv_fwd(b: usize, ci: usize, h: usize, w: usize, co: usize, k: (usize, usize), s: (usize, usize)) -> String {
        format!("conv_fwd_b{b}_ci{ci}_h{h}_w{w}_co{co}_k{}x{}_s{}x{}", k.0, k.1, s.0, s.1)
    }

    /// Conv backward artifact name.
    pub fn conv_bwd(b: usize, ci: usize, h: usize, w: usize, co: usize, k: (usize, usize), s: (usize, usize)) -> String {
        format!("conv_bwd_b{b}_ci{ci}_h{h}_w{w}_co{co}_k{}x{}_s{}x{}", k.0, k.1, s.0, s.1)
    }

    /// Affine forward artifact name (with bias).
    pub fn affine_fwd(b: usize, fi: usize, fo: usize, bias: bool) -> String {
        if bias {
            format!("affine_fwd_b{b}_fi{fi}_fo{fo}")
        } else {
            format!("affine_fwd_nobias_b{b}_fi{fi}_fo{fo}")
        }
    }

    /// Affine backward artifact name.
    pub fn affine_bwd(b: usize, fi: usize, fo: usize) -> String {
        format!("affine_bwd_b{b}_fi{fi}_fo{fo}")
    }
}

/// [`LocalKernels`] backend over the PJRT runtime with native fallback.
pub struct PjrtKernels {
    rt: PjrtRuntime,
    native: NativeKernels,
    /// Count of artifact-served calls (perf evidence).
    pub hits: std::sync::atomic::AtomicUsize,
    /// Count of native-fallback calls.
    pub misses: std::sync::atomic::AtomicUsize,
}

impl PjrtKernels {
    /// Load the backend from an artifacts directory.
    pub fn load(dir: &str) -> Result<PjrtKernels> {
        Ok(PjrtKernels {
            rt: PjrtRuntime::new(dir)?,
            native: NativeKernels,
            hits: Default::default(),
            misses: Default::default(),
        })
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }

    fn hit(&self) {
        self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn miss(&self) {
        self.misses
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

impl LocalKernels<f32> for PjrtKernels {
    fn conv2d_forward(
        &self,
        x: &Tensor<f32>,
        w: &Tensor<f32>,
        bias: Option<&Tensor<f32>>,
        spec: Conv2dSpec,
    ) -> Result<Tensor<f32>> {
        let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let (co, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
        let name = names::conv_fwd(b, ci, h, wd, co, (kh, kw), spec.stride);
        if spec.dilation == (1, 1) && bias.is_some() && self.rt.has(&name) {
            self.hit();
            let out = self
                .rt
                .run(&name, vec![x.clone(), w.clone(), bias.unwrap().clone()])?;
            return Ok(out.into_iter().next().expect("conv_fwd returns y"));
        }
        self.miss();
        self.native.conv2d_forward(x, w, bias, spec)
    }

    fn conv2d_backward(
        &self,
        x: &Tensor<f32>,
        w: &Tensor<f32>,
        dy: &Tensor<f32>,
        spec: Conv2dSpec,
    ) -> Result<(Tensor<f32>, Tensor<f32>, Tensor<f32>)> {
        let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let (co, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
        let name = names::conv_bwd(b, ci, h, wd, co, (kh, kw), spec.stride);
        if spec.dilation == (1, 1) && self.rt.has(&name) {
            self.hit();
            let mut out = self
                .rt
                .run(&name, vec![x.clone(), w.clone(), dy.clone()])?
                .into_iter();
            let dx = out.next().expect("dx");
            let dw = out.next().expect("dw");
            let db = out.next().expect("db");
            return Ok((dx, dw, db));
        }
        self.miss();
        self.native.conv2d_backward(x, w, dy, spec)
    }

    fn pool2d_forward(
        &self,
        x: &Tensor<f32>,
        spec: Pool2dSpec,
    ) -> Result<(Tensor<f32>, Vec<usize>)> {
        // Memory-bound; stays native (see module docs).
        self.native.pool2d_forward(x, spec)
    }

    fn pool2d_backward(
        &self,
        x_shape: &[usize],
        dy: &Tensor<f32>,
        argmax: &[usize],
        spec: Pool2dSpec,
    ) -> Result<Tensor<f32>> {
        self.native.pool2d_backward(x_shape, dy, argmax, spec)
    }

    fn affine_forward(
        &self,
        x: &Tensor<f32>,
        w: &Tensor<f32>,
        bias: Option<&Tensor<f32>>,
    ) -> Result<Tensor<f32>> {
        let (b, fi) = (x.shape()[0], x.shape()[1]);
        let fo = w.shape()[0];
        let name = names::affine_fwd(b, fi, fo, bias.is_some());
        if self.rt.has(&name) {
            self.hit();
            let mut inputs = vec![x.clone(), w.clone()];
            if let Some(bias) = bias {
                inputs.push(bias.clone());
            }
            let out = self.rt.run(&name, inputs)?;
            return Ok(out.into_iter().next().expect("affine_fwd returns y"));
        }
        self.miss();
        self.native.affine_forward(x, w, bias)
    }

    fn affine_backward(
        &self,
        x: &Tensor<f32>,
        w: &Tensor<f32>,
        dy: &Tensor<f32>,
    ) -> Result<(Tensor<f32>, Tensor<f32>, Tensor<f32>)> {
        let (b, fi) = (x.shape()[0], x.shape()[1]);
        let fo = w.shape()[0];
        let name = names::affine_bwd(b, fi, fo);
        if self.rt.has(&name) {
            self.hit();
            let mut out = self
                .rt
                .run(&name, vec![x.clone(), w.clone(), dy.clone()])?
                .into_iter();
            let dx = out.next().expect("dx");
            let dw = out.next().expect("dw");
            let db = out.next().expect("db");
            return Ok((dx, dw, db));
        }
        self.miss();
        self.native.affine_backward(x, w, dy)
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    /// AOT artifacts are dispatched by exact input shape; slab-shaped
    /// inputs would never match one and every overlap-path call would
    /// silently demote to the native fallback — so the conv layer must
    /// not feed this backend slabs. A capability, not a name test: a
    /// renamed or third shape-exact backend inherits the safe answer by
    /// overriding this too.
    fn supports_slab_dispatch(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("distdl_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [{"name": "affine_fwd_b4_fi3_fo2", "file": "a.hlo.txt",
                 "inputs": [[4,3],[2,3],[2]], "num_outputs": 1}]}"#,
        )
        .unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries["affine_fwd_b4_fi3_fo2"];
        assert_eq!(e.inputs, vec![vec![4, 3], vec![2, 3], vec![2]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn name_builders() {
        assert_eq!(
            names::conv_fwd(64, 1, 18, 18, 6, (5, 5), (1, 1)),
            "conv_fwd_b64_ci1_h18_w18_co6_k5x5_s1x1"
        );
        assert_eq!(names::affine_fwd(64, 200, 60, false), "affine_fwd_nobias_b64_fi200_fo60");
        assert_eq!(names::affine_bwd(64, 200, 60), "affine_bwd_b64_fi200_fo60");
    }
}
