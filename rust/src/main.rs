//! `distdl` — the leader entrypoint and CLI.
//!
//! ```text
//! distdl train         [--batch N] [--steps N] [--lr F] [--seed N]
//!                      [--sequential] [--backend native|pjrt]
//!                      [--dataset N] [--config file.json] [--metrics out.json]
//!                      [--checkpoint-every N] [--checkpoint-dir DIR]
//!                      [--resume-from DIR/step_NNNNNN] [--fault-plan SPEC]
//!                      [--preflight] [--transport channel|tcp|unix]
//! distdl parity        [--batch N] [--steps N]       sequential vs distributed (§5)
//! distdl describe      [--batch N]                   Table 1 / Fig. C10 placement
//! distdl adjoint-test  [--size N]                    Eq. (13) across all primitives
//! distdl halo-table                                  Appendix B halo geometries
//! distdl check         [--geometry NAME] [--batch N] static communication-plan
//!                      [--transport channel|tcp|unix]
//!                                                    verifier: captures every
//!                                                    geometry's message schedule
//!                                                    (no kernel math) and checks
//!                                                    endpoints, tags, deadlock
//!                                                    freedom, adjoint duality,
//!                                                    and pool balance; exits
//!                                                    non-zero on any finding
//! ```

use distdl::cli::Args;
use distdl::config::{Backend, TrainConfig};
use distdl::error::{Error, Result};

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("parity") => cmd_parity(&args),
        Some("describe") => cmd_describe(&args),
        Some("adjoint-test") => cmd_adjoint(&args),
        Some("halo-table") => cmd_halo_table(),
        Some("check") => cmd_check(&args),
        Some("version") => {
            println!("distdl {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        Some(other) => Err(Error::Usage(format!(
            "unknown command '{other}' (try: train, parity, describe, adjoint-test, halo-table, check)"
        ))),
        None => {
            println!(
                "distdl — linear-algebraic model parallelism (Hewett & Grady 2020)\n\
                 commands: train, parity, describe, adjoint-test, halo-table, check, version\n\
                 see README.md for details"
            );
            Ok(())
        }
    }
}

fn config_from(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_json_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(b) = args.get_usize("batch")? {
        cfg.batch = b;
    }
    if let Some(s) = args.get_usize("steps")? {
        cfg.steps = s;
    }
    if let Some(lr) = args.get_f64("lr")? {
        cfg.lr = lr;
    }
    if let Some(d) = args.get_usize("dataset")? {
        cfg.dataset = d;
    }
    if let Some(seed) = args.get_usize("seed")? {
        cfg.seed = seed as u64;
    }
    if args.has_flag("sequential") {
        cfg.distributed = false;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    if let Some(n) = args.get_usize("checkpoint-every")? {
        cfg.checkpoint_every = n;
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = dir.to_string();
    }
    if let Some(dir) = args.get("resume-from") {
        cfg.resume_from = Some(dir.to_string());
    }
    if let Some(plan) = args.get("fault-plan") {
        cfg.fault_plan = Some(plan.to_string());
    }
    if args.has_flag("preflight") {
        cfg.preflight_check = true;
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = Some(distdl::comm::TransportKind::parse(t)?);
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    println!(
        "training LeNet-5: layout={} backend={:?} batch={} steps={} lr={}",
        if cfg.distributed {
            "4-worker distributed"
        } else {
            "sequential"
        },
        cfg.backend,
        cfg.batch,
        cfg.steps,
        cfg.lr
    );
    let report = distdl::coordinator::train(&cfg)?;
    for rec in report
        .log
        .steps
        .iter()
        .filter(|r| r.step % cfg.log_every == 0 || r.step + 1 == cfg.steps)
    {
        println!(
            "step {:>5}  loss {:>8.4}  acc {:>6.2}%  ({:.3}s)",
            rec.step,
            rec.loss,
            rec.accuracy * 100.0,
            rec.step_time_s
        );
    }
    println!(
        "final: loss {:.4}, train acc {:.2}%, eval acc {}",
        report.final_loss,
        report.final_accuracy * 100.0,
        report
            .eval_accuracy
            .map(|a| format!("{:.2}%", a * 100.0))
            .unwrap_or_else(|| "n/a".into()),
    );
    println!("params per rank: {:?}", report.params_per_rank);
    if let Some(path) = args.get("metrics") {
        std::fs::write(path, report.log.to_json().to_string())?;
        println!("metrics written to {path}");
    }
    Ok(())
}

fn cmd_parity(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    cfg.distributed = false;
    println!("== sequential ==");
    let seq = distdl::coordinator::train(&cfg)?;
    cfg.distributed = true;
    println!("== distributed (4 workers) ==");
    let dist = distdl::coordinator::train(&cfg)?;
    println!(
        "\n§5 parity: sequential loss {:.6} acc {:.2}% | distributed loss {:.6} acc {:.2}%",
        seq.final_loss,
        seq.final_accuracy * 100.0,
        dist.final_loss,
        dist.final_accuracy * 100.0
    );
    let max_dl = seq
        .log
        .steps
        .iter()
        .zip(dist.log.steps.iter())
        .map(|(a, b)| (a.loss - b.loss).abs())
        .fold(0.0f64, f64::max);
    println!("max per-step |Δloss| = {max_dl:.3e} (identical data, identical init)");
    Ok(())
}

fn cmd_describe(args: &Args) -> Result<()> {
    use distdl::models::{lenet5, LeNetConfig, LeNetLayout};
    use distdl::nn::NativeKernels;
    let batch = args.get_usize("batch")?.unwrap_or(256);
    let net = lenet5::<f32>(
        &LeNetConfig {
            batch,
            layout: LeNetLayout::FourWorker,
        },
        std::sync::Arc::new(NativeKernels),
    )?;
    println!("Distributed LeNet-5, batch {batch} — Table 1 (learnable parameters per worker):\n");
    println!(
        "{:<10} {:<26} {:<16} {:<26} {:<16}",
        "Layer", "Worker 0", "Worker 1", "Worker 2", "Worker 3"
    );
    let reports: Vec<_> = (0..4).map(|r| net.placement_report(r)).collect();
    for li in 0..reports[0].len() {
        let lname = &reports[0][li].0;
        let mut cells = Vec::new();
        for r in &reports {
            let placement = &r[li].1;
            if placement.is_empty() {
                cells.push("None".to_string());
            } else {
                cells.push(
                    placement
                        .iter()
                        .map(|(n, s)| format!("{n}: {s:?}"))
                        .collect::<Vec<_>>()
                        .join("  "),
                );
            }
        }
        if cells.iter().any(|c| c != "None") {
            println!(
                "{:<10} {:<26} {:<16} {:<26} {:<16}",
                lname, cells[0], cells[1], cells[2], cells[3]
            );
        }
    }
    Ok(())
}

fn cmd_adjoint(args: &Args) -> Result<()> {
    let size = args.get_usize("size")?.unwrap_or(16);
    distdl::coordinator::suites::run_adjoint_suite(size)
}

fn cmd_halo_table() -> Result<()> {
    distdl::coordinator::suites::print_halo_tables();
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    use distdl::analysis::{shipped_geometries, verify, Geometry};
    let batch = args.get_usize("batch")?.unwrap_or(8);
    // Capture the plans over the requested backend — the schedule must be
    // transport-independent, so a socket capture catching a discrepancy
    // is itself a finding.
    let _transport = match args.get("transport") {
        Some(t) => Some(distdl::comm::TransportGuard::set(
            distdl::comm::TransportKind::parse(t)?,
        )),
        None => None,
    };
    let selected: Vec<(String, Geometry)> = match args.get("geometry") {
        Some(name) => {
            let g = Geometry::from_name(name).ok_or_else(|| {
                let known: Vec<&str> = shipped_geometries().iter().map(|(n, _)| *n).collect();
                Error::Usage(format!(
                    "unknown geometry '{name}' (known: {})",
                    known.join(", ")
                ))
            })?;
            vec![(name.to_string(), g)]
        }
        None => shipped_geometries()
            .into_iter()
            .map(|(n, g)| (n.to_string(), g))
            .collect(),
    };
    let mut dirty = 0usize;
    for (name, geometry) in &selected {
        let graph = geometry.capture(batch)?;
        let report = verify(&graph);
        println!("{name:<14} {report}");
        if !report.is_clean() {
            dirty += 1;
        }
    }
    if dirty > 0 {
        return Err(Error::Config(format!(
            "plan check failed for {dirty} of {} geometries",
            selected.len()
        )));
    }
    Ok(())
}
