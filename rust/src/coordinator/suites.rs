//! Shared experiment suites: the Eq. (13) adjoint-coherence sweep (E1),
//! its chaos variant (the same sweep under a deterministic fault plan,
//! asserting bitwise parity with the fault-free run), and the Appendix-B
//! halo-geometry tables (E2–E5), used by the CLI, the
//! `adjoint_suite`/`halo_explorer` examples, and the benches.

use crate::adjoint::{adjoint_residual, adjoint_residual_under, DistLinearOp};
use crate::comm::faults::FaultPlan;
use crate::error::{Error, Result};
use crate::halo::{dim_halos, format_dim_table, HaloGeometry, KernelSpec};
use crate::partition::{Partition, TensorDecomposition};
use crate::primitives::{
    AllReduce, Broadcast, Gather, HaloExchange, PipeMove, Repartition, RingAllReduce, Scatter,
    SendRecv, SumReduce, TrimPad,
};

/// One adjoint-suite case: a named operator with the world size it runs
/// on.
pub struct SuiteCase {
    /// Case label.
    pub label: String,
    /// World size.
    pub world: usize,
    /// The operator.
    pub op: Box<dyn DistLinearOp<f64>>,
}

/// Build the full primitive sweep at a given tensor scale `n`.
pub fn suite_cases(n: usize) -> Result<Vec<SuiteCase>> {
    let mut cases: Vec<SuiteCase> = Vec::new();
    // send-recv
    cases.push(SuiteCase {
        label: format!("send-recv [{n}x{n}] 0→1"),
        world: 2,
        op: Box::new(SendRecv::new(0, 1, &[n, n], 10)),
    });
    // broadcast / sum-reduce / all-reduce over 4 workers
    cases.push(SuiteCase {
        label: format!("broadcast [{n}x{n}] 1→4"),
        world: 4,
        op: Box::new(Broadcast::replicate(0, 4, &[n, n], 20)?),
    });
    cases.push(SuiteCase {
        label: format!("sum-reduce [{n}x{n}] 4→1"),
        world: 4,
        op: Box::new(SumReduce::to_root(0, 4, &[n, n], 30)?),
    });
    cases.push(SuiteCase {
        label: format!("all-reduce [{n}] x4"),
        world: 4,
        op: Box::new(AllReduce::new(&[0, 1, 2, 3], &[n], 40)?),
    });
    // scatter / gather over a 2-D decomposition
    let d22 = TensorDecomposition::new(Partition::from_shape(&[2, 2]), &[2 * n + 1, n + 2])?;
    cases.push(SuiteCase {
        label: format!("scatter [{}x{}] root 0 → 2x2", 2 * n + 1, n + 2),
        world: 4,
        op: Box::new(Scatter::new(d22.clone(), 0, 50)),
    });
    cases.push(SuiteCase {
        label: format!("gather [{}x{}] 2x2 → root 1", 2 * n + 1, n + 2),
        world: 4,
        op: Box::new(Gather::new(d22, 1, 60)),
    });
    // all-to-all: rows → columns
    cases.push(SuiteCase {
        label: format!("all-to-all [{n}x{n}] rows→cols"),
        world: 2,
        op: Box::new(Repartition::new(
            TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[n, n])?,
            TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[n, n])?,
            70,
        )?),
    });
    // halo exchanges for every Appendix-B geometry, scaled by n
    for (label, size, p, k) in [
        ("halo B2 (k5 pad2)", 11.max(n), 3, KernelSpec::padded(5, 2)),
        ("halo B3 (k5)", 11.max(n), 3, KernelSpec::plain(5)),
        ("halo B5 (k2 s2)", 20.max(n), 6, KernelSpec::pool(2, 2)),
    ] {
        let geom = HaloGeometry::new(&[size], &[p], &[k])?;
        cases.push(SuiteCase {
            label: format!("{label} n={size} P={p}"),
            world: p,
            op: Box::new(HaloExchange::new(Partition::from_shape(&[p]), geom.clone(), 80)?),
        });
        cases.push(SuiteCase {
            label: format!("trim/pad shim {label} n={size} P={p}"),
            world: p,
            op: Box::new(TrimPad::new(Partition::from_shape(&[p]), geom)),
        });
    }
    // 2-D unbalanced halo exchange (Appendix B.2)
    let geom2 = HaloGeometry::new(
        &[2 * n + 1, 2 * n + 3],
        &[2, 2],
        &[KernelSpec::plain(3), KernelSpec::plain(3)],
    )?;
    cases.push(SuiteCase {
        label: format!("halo 2-D unbalanced [{0}x{1}] 2x2", 2 * n + 1, 2 * n + 3),
        world: 4,
        op: Box::new(HaloExchange::new(Partition::from_shape(&[2, 2]), geom2, 90)?),
    });
    Ok(cases)
}

/// The primitive sweep plus the two derived streaming operators — the
/// ring all-reduce and the pipeline stage boundary — whose multi-step
/// schedules give fault injection the most sequence numbers to attack.
pub fn chaos_cases(n: usize) -> Result<Vec<SuiteCase>> {
    let mut cases = suite_cases(n)?;
    cases.push(SuiteCase {
        label: format!("ring all-reduce [{}] x4", 4 * n),
        world: 4,
        op: Box::new(RingAllReduce::averaging(&[0, 1, 2, 3], &[4 * n], 100)?),
    });
    cases.push(SuiteCase {
        label: format!("pipe-move [{n}x{n}] 0→1"),
        world: 2,
        op: Box::new(PipeMove::new(0, 1, &[n, n], 110)),
    });
    Ok(cases)
}

/// Run the Eq. (13) sweep under a deterministic fault plan.
///
/// Every case runs twice — fault-free and with `plan_spec` installed on
/// each endpoint — and the faulted residual must be **bitwise identical**
/// to the clean one (which itself must be coherent): the engine's
/// resequencing/dedup/retransmit layer repairs the injected
/// delays/duplicates/reorders/drops below the primitive, so the
/// primitive's arithmetic never sees them.
pub fn run_adjoint_chaos_suite(n: usize, plan_spec: &str) -> Result<()> {
    let plan = FaultPlan::parse(plan_spec)?;
    for case in chaos_cases(n)? {
        let clean = adjoint_residual(case.world, case.op.as_ref(), 0xE13)?;
        if clean >= 1e-12 {
            return Err(Error::Primitive(format!(
                "{}: fault-free residual {clean:.3e} is incoherent",
                case.label
            )));
        }
        let faulted = adjoint_residual_under(case.world, case.op.as_ref(), 0xE13, Some(&plan))?;
        if faulted.to_bits() != clean.to_bits() {
            return Err(Error::Primitive(format!(
                "{}: residual under faults {faulted:.17e} != fault-free {clean:.17e} \
                 (plan '{plan_spec}')",
                case.label
            )));
        }
    }
    Ok(())
}

/// Run the Eq. (13) sweep, printing a row per primitive; errors if any
/// residual exceeds the f64 coherence threshold.
pub fn run_adjoint_suite(n: usize) -> Result<()> {
    println!("Eq. (13) adjoint coherence, f64, tensor scale n={n}:");
    println!("{:<48} {:>8} {:>14}", "operator", "world", "residual");
    let mut worst: f64 = 0.0;
    for case in suite_cases(n)? {
        let r = adjoint_residual(case.world, case.op.as_ref(), 0xE13)?;
        println!("{:<48} {:>8} {:>14.3e}", case.label, case.world, r);
        worst = worst.max(r);
    }
    println!("worst residual: {worst:.3e} (threshold 1e-12)");
    if worst >= 1e-12 {
        return Err(Error::Primitive(format!(
            "adjoint suite failed: worst residual {worst:.3e}"
        )));
    }
    Ok(())
}

/// Print the Appendix-B halo tables (E2–E5).
pub fn print_halo_tables() {
    let figures: [(&str, usize, usize, KernelSpec); 4] = [
        ("Fig. B2 — 'normal' convolution (k=5, pad=2)", 11, 3, KernelSpec::padded(5, 2)),
        ("Fig. B3 — unbalanced convolution (k=5, no pad)", 11, 3, KernelSpec::plain(5)),
        ("Fig. B4 — simple unbalanced pooling (k=2, s=2)", 11, 3, KernelSpec::pool(2, 2)),
        ("Fig. B5 — complex unbalanced pooling (k=2, s=2)", 20, 6, KernelSpec::pool(2, 2)),
    ];
    for (title, n, p, k) in figures {
        println!("\n{title}");
        match dim_halos(n, p, &k) {
            Ok(halos) => print!("{}", format_dim_table(n, &k, &halos)),
            Err(e) => println!("  error: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_runs_clean_small() {
        run_adjoint_suite(8).unwrap();
    }

    /// Satellite sweep: every primitive plus ring and pipe-move stays
    /// Eq. 13-coherent — bitwise equal to fault-free — under injected
    /// delay/duplicate and reorder/duplicate/drop plans. Both plans in
    /// one test so the cluster-heavy sweeps don't multiply wall time.
    #[test]
    fn chaos_suite_is_bitwise_clean() {
        run_adjoint_chaos_suite(6, "seed=7;delay:p=0.35,ms=2;dup:p=0.35").unwrap();
        run_adjoint_chaos_suite(6, "seed=11;retry_ms=5;reorder:p=0.4,ms=1;dup:p=0.2;drop:p=0.15")
            .unwrap();
    }

    #[test]
    fn suite_case_inventory() {
        let cases = suite_cases(8).unwrap();
        // all seven primitive families present
        let labels: Vec<&str> = cases.iter().map(|c| c.label.as_str()).collect();
        for needle in [
            "send-recv",
            "broadcast",
            "sum-reduce",
            "all-reduce",
            "scatter",
            "gather",
            "all-to-all",
            "halo",
            "trim/pad",
        ] {
            assert!(
                labels.iter().any(|l| l.contains(needle)),
                "missing {needle} in suite"
            );
        }
    }
}
