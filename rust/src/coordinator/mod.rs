//! The training coordinator — the SPMD orchestrator that reproduces the
//! §5 / Appendix C experiment.
//!
//! [`train`] launches a [`crate::comm::Cluster`] (one thread per world
//! rank), builds the LeNet-5 [`crate::autograd::Network`] on every rank
//! (cheap, description-only), initialises per-rank parameter shards from a
//! shared seed, and runs the synchronous training loop: scatter batch →
//! distributed forward → loss at root → distributed backward → local
//! optimizer step. Python never appears anywhere on this path; local
//! compute goes through the configured [`LocalKernels`] backend (native
//! Rust or AOT XLA/Pallas executables).
//!
//! On the nonblocking comm engine the loop is lightly pipelined: the next
//! micro-batch's input tensor is prepared in the overlap window between
//! the backward pass (whose gradient sum-reduce sends are posted eagerly)
//! and the local optimizer step, and the engine's in-flight/wait-time
//! counters are surfaced on the [`MetricLog`] (`comm_*` meta keys). Each
//! rank thread owns a [`crate::memory`] scratch arena that the layer
//! kernels stage im2col columns, GEMM pack panels, and broadcast replicas
//! in, and each rank's comm endpoint owns a registered buffer pool that
//! every message payload (halo pieces, the broadcast/sum-reduce trees,
//! scatter/gather, all-to-all) is staged in; rank 0's counters land on
//! the log as `scratch_*` and `comm_pool_*` keys — after warm-up,
//! steady-state steps should add nothing to `scratch_allocations` or
//! `comm_pool_misses`: the entire train step stops touching the
//! allocator. The loop pre-warms each endpoint's pool for the pipeline's
//! rotation depth ([`PIPELINE_POOL_DEPTH`] via `Comm::pool_reserve`), so
//! a pipelined size class misses at most twice — its second miss mints
//! the rest of the rotation — rather than once per step while the
//! rotation is minted buffer by buffer. Receive sides hand the layers
//! **pool-backed tensors** (`tensor_pool_backed` on the log), consumed
//! read-only, so `tensor_cow_promotions` staying flat is the evidence
//! that zero allocations also means zero copies.
//!
//! With `replicas > 1` ([`crate::config::TrainConfig::replicas`]) the run
//! goes hybrid data×model parallel: the world factors as
//! `replicas × model-grid` ([`crate::partition::HybridTopology`]), each
//! replica runs the same model partition (rank-offset by `k·M`) on its
//! own micro-batch stripe, and [`train_step_hybrid`] hooks the
//! [`crate::optim::dp::DataParallel`] engine into the backward pass so
//! gradient buckets ring-average across replicas *inside* the backward
//! overlap window. `set_dp_overlap(false)` serialises the averaging after
//! backward — bitwise-identical results, used as the parity reference.
//!
//! With `stages > 1` ([`crate::config::TrainConfig::stages`]) the run is
//! pipeline parallel on the third topology axis: the sequential layer
//! tape is cut into contiguous stages (one rank each,
//! [`crate::models::lenet5_pipeline`]) and each step's batch streams
//! through them as `micro_batches` micro-batches on the
//! [`crate::optim::pp`] engine's 1F1B schedule (S = 4 stages, m = 6
//! micro-batches shown; `Fk`/`Bk` = micro-batch `k`'s forward/backward):
//!
//! ```text
//!            ├─ warm-up ─┤├───── 1F1B steady state ─────┤├─ drain ─┤
//! stage 0 :  F0 F1 F2     F3 B0 F4 B1 F5 B2              B3 B4 B5
//! stage 1 :     F0 F1     F2 B0 F3 B1 F4 B2 F5 B3        B4 B5
//! stage 2 :        F0     F1 B0 F2 B1 F3 B2 F4 B3 F5 B4  B5
//! stage 3 :               F0 B0 F1 B1 F2 B2 F3 B3 F4 B4  F5 B5
//! ```
//!
//! Stage-boundary activations ride forward and their cotangents ride
//! back as pool-staged messages (`primitives::PipeMove` — an adjoint
//! pair, Eq. 13-coherent like every other movement primitive), gradients
//! accumulate across micro-batches, and with `replicas > 1` the DP ring
//! hook fires during the *last* micro-batch's backward so all three
//! parallel axes share one overlap window. Every stage's weight update
//! is local; a barrier closes each step's epoch. The per-stage idle
//! time, measured pipeline bubble (vs the analytic `(S−1)/(S−1+m)`), and
//! in-flight queue depth surface on the log as `pp_*` meta keys.
//! `optim::pp::set_pp_overlap(false)` removes the warm-up — a fully
//! serialized lockstep schedule with bitwise-identical gradients, the
//! parity reference and the bench baseline.
//!
//! ## Failure model
//!
//! The training loop composes the comm engine's failure story (see
//! [`crate::comm`]) with checkpoint/restore ([`crate::checkpoint`]):
//!
//! * **What is retried** is entirely below this layer: late, duplicated,
//!   reordered, or corrupted-and-recovered messages are absorbed by the
//!   engine's sequence numbers and retry/retransmit clocks, so every
//!   fault plan that injects only recoverable faults yields **bitwise
//!   identical** gradients, parameters, and metrics — asserted by
//!   `tests/fault_tolerance.rs` over full DP×PP steps.
//! * **What is fatal** — a receive outliving its fatal deadline, or a
//!   rank scheduled to die by a `kill:rank=R,step=K` plan clause
//!   ([`crate::comm::Comm::fault_step`], checked at the top of every
//!   step) — errors out of [`train`].
//! * **What checkpointing covers**: with
//!   [`TrainConfig::checkpoint_every`] set, every rank snapshots its
//!   parameters, Adam state, and step index at the cadence boundary
//!   ([`crate::checkpoint`]); `TrainConfig::resume_from` restarts from a
//!   step directory and replays the uninterrupted run bit for bit. What
//!   is *not* covered: in-flight messages (a resume restarts the step
//!   from its boundary) and the metric log of pre-kill steps.
//! * **Health surfacing**: rank 0's fault/retry/straggler counters land
//!   on the log as `fault_*` keys ([`MetricLog::set_fault_stats`]), and
//!   every rank's counters land as `fault_rank{r}_*` keys
//!   ([`MetricLog::set_fault_stats_for`]) — a straggling or
//!   retransmit-heavy rank is visible by rank, not averaged into a
//!   world-wide blur.
//!
//! ## Analysis / pre-flight
//!
//! With [`TrainConfig::preflight_check`] set, [`train`] and the pipeline
//! path run the static communication-plan verifier ([`crate::analysis`])
//! before launching the cluster: the run's geometry (layout × replicas ×
//! stages) is captured in plan-capture mode — every send, receive,
//! completion, and barrier the schedule would issue, with zero kernel
//! math — and checked for endpoint mismatches, tag collisions,
//! deadlocks, adjoint-duality violations, and staging-pool leaks. Any
//! finding aborts with [`Error::Config`] before the first step; the same
//! sweep is available standalone as the `check` CLI subcommand.

use crate::autograd::NetworkState;
use crate::checkpoint::Checkpoint;
use crate::comm::faults::{FaultPlan, FaultStats};
use crate::comm::{Cluster, Comm, CommGroup};
use crate::config::{Backend, TrainConfig};
use crate::data::{Batch, SyntheticMnist};
use crate::error::{Error, Result};
use crate::metrics::{MetricLog, StepRecord};
use crate::models::{lenet5_at, lenet5_pipeline, LeNetConfig, LeNetLayout};
use crate::nn::native::{count_correct, cross_entropy_backward, cross_entropy_forward};
use crate::nn::{LocalKernels, NativeKernels};
use crate::optim::dp::{dp_overlap, DataParallel};
use crate::optim::pp::{analytic_bubble, pp_overlap, Pipeline, PipelineStats};
use crate::optim::Adam;
use crate::partition::HybridTopology;
use crate::tensor::Tensor;
use crate::util::timer::Timer;
use std::sync::Arc;

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Per-step metrics (recorded at the loss root).
    pub log: MetricLog,
    /// Final-quarter mean training accuracy.
    pub final_accuracy: f64,
    /// Final-quarter mean training loss.
    pub final_loss: f64,
    /// Per-rank parameter counts (Table-1 style evidence).
    pub params_per_rank: Vec<usize>,
    /// World size used.
    pub world: usize,
    /// Held-out evaluation accuracy, if evaluation was run.
    pub eval_accuracy: Option<f64>,
}

/// Build the kernel backend for one rank.
pub fn kernels_for(backend: Backend, artifacts_dir: &str) -> Result<Arc<dyn LocalKernels<f32>>> {
    match backend {
        Backend::Native => Ok(Arc::new(NativeKernels)),
        Backend::Pjrt => Ok(Arc::new(crate::runtime::PjrtKernels::load(artifacts_dir)?)),
    }
}

/// Registered-pool pre-warm depth the training loop hands
/// [`Comm::pool_reserve`]. The pipeline keeps up to this many buffers of
/// one message size class in flight at once — the broadcast replicas a
/// layer stashes from forward to backward, plus the micro-batch prefetch
/// riding the gradient sum-reduce tail — so without pre-warming the first
/// few steps each mint one more buffer per class and show up as spurious
/// pool misses. With it, a pipelined class misses at most twice (its
/// second miss mints the rest of the rotation) and within-step classes
/// exactly once, so a two-step warm-up is genuinely warm.
pub const PIPELINE_POOL_DEPTH: usize = 3;

/// Tag base for the data-parallel ring buckets. The model-parallel layer
/// tags grow in 10 000 strides from 0 and stay far below this, so the DP
/// rings (bucket `i` on `DP_TAG_BASE + i`) never collide with them.
pub const DP_TAG_BASE: u64 = 1_000_000;

/// Parse the config's fault plan for installation on every endpoint.
/// `TrainConfig::validate` already vetted the grammar; this is the
/// authoritative parse the training loop installs.
fn planned_faults(cfg: &TrainConfig) -> Result<Option<FaultPlan>> {
    cfg.fault_plan.as_deref().map(FaultPlan::parse).transpose()
}

/// Restore a rank's training state from `cfg.resume_from` (if set),
/// validating that the checkpoint matches this run's world size and seed
/// — restored parameters only reproduce the uninterrupted run if the
/// data/batch stream matches. Returns the step index to continue from
/// (0 on a fresh start).
fn maybe_resume(
    cfg: &TrainConfig,
    world: usize,
    rank: usize,
    state: &mut NetworkState<f32>,
    opt: &mut Adam<f32>,
) -> Result<usize> {
    let Some(dir) = &cfg.resume_from else {
        return Ok(0);
    };
    let ck = Checkpoint::<f32>::load(std::path::Path::new(dir), rank)?;
    if ck.world != world {
        return Err(Error::Config(format!(
            "checkpoint world size {} != this run's {world}",
            ck.world
        )));
    }
    if ck.seed != cfg.seed {
        return Err(Error::Config(format!(
            "checkpoint seed {} != this run's {}",
            ck.seed, cfg.seed
        )));
    }
    ck.apply(state, opt)?;
    Ok(ck.step as usize)
}

/// Snapshot a rank's training state at the `checkpoint_every` cadence
/// (`done_steps` completed steps so far).
fn maybe_checkpoint(
    cfg: &TrainConfig,
    world: usize,
    rank: usize,
    done_steps: usize,
    state: &NetworkState<f32>,
    opt: &Adam<f32>,
) -> Result<()> {
    if cfg.checkpoint_every > 0 && done_steps % cfg.checkpoint_every == 0 {
        Checkpoint::capture(world, rank, cfg.seed, done_steps as u64, state, opt)
            .save(&cfg.checkpoint_dir)?;
    }
    Ok(())
}

/// Run the §5 training experiment per `cfg`, returning the report.
///
/// With `cfg.replicas > 1` the run is hybrid data×model parallel: the
/// world is `replicas × layout.world_size()` ranks, replica `k` holds the
/// model partition offset by `k · M` ([`lenet5_at`]) and trains on its own
/// `batch / replicas` micro-batch, and each rank ring-averages its
/// gradient shards with its [`HybridTopology::dp_group`] peers inside the
/// backward overlap window before the (local) optimizer step.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    cfg.validate()?;
    // Pin the configured backend for every cluster this run launches
    // (including the pipeline path and the pre-flight plan capture, which
    // must see the same transport the training run uses).
    let _transport = cfg.transport.map(crate::comm::TransportGuard::set);
    if cfg.stages > 1 {
        return train_pipeline(cfg);
    }
    let layout = if cfg.distributed {
        LeNetLayout::FourWorker
    } else {
        LeNetLayout::Sequential
    };
    let topo = HybridTopology::new(cfg.replicas, layout.world_size())?;
    let world = topo.world();
    let replicas = cfg.replicas;
    let micro = cfg.batch / replicas;
    let data = SyntheticMnist::new(cfg.seed ^ 0xDA7A, cfg.dataset);
    let train_batches = data.batches(micro);
    if train_batches.is_empty() {
        return Err(Error::Config("dataset produced no full batches".into()));
    }
    let eval_data = SyntheticMnist::new(cfg.seed ^ 0xE7A1, (cfg.batch * 4).max(256));
    let eval_batches = eval_data.batches(micro);
    let model_cfg = LeNetConfig {
        batch: micro,
        layout,
    };
    let fault_plan = planned_faults(cfg)?;
    if cfg.preflight_check {
        crate::analysis::preflight(cfg)?;
    }

    let per_rank = Cluster::run(world, |comm| {
        // Pre-warm the registered buffer pool for the pipeline's rotation
        // depth: a pipelined message size class mints its full in-flight
        // complement on its second miss instead of one per step.
        comm.pool_reserve(PIPELINE_POOL_DEPTH);
        let rank = comm.rank();
        let replica = topo.replica_of(rank);
        // Replica k's network is replica 0's with every rank offset by
        // k·M; its loss root is the replica's first rank.
        let root = topo.world_rank(replica, 0);
        let kernels = kernels_for(cfg.backend, &cfg.artifacts_dir)?;
        let net = lenet5_at::<f32>(&model_cfg, kernels, root)?;
        // Layer init derives global parameters from the seed alone and
        // slices per grid cell, so all replicas start bit-identical.
        let mut state = net.init(rank, cfg.seed)?;
        let mut opt = Adam::new(cfg.lr);
        if let Some(plan) = fault_plan.clone() {
            comm.set_fault_plan(Some(plan));
        }
        let start = maybe_resume(cfg, world, rank, &mut state, &mut opt)?;
        let mut dp = DataParallel::<f32>::for_rank(&topo, rank, DP_TAG_BASE);
        let mut log = MetricLog::new();
        log.set_meta("layout", format!("{layout:?}"));
        log.set_meta("backend", format!("{:?}", cfg.backend));
        log.set_meta("batch", cfg.batch);
        log.set_meta("lr", cfg.lr);
        // Micro-batches are replica-striped: at step t replica k trains
        // on micro-batch t·R + k, so together the replicas consume
        // exactly the samples of step t's full batch — averaging the
        // gradients with 1/R recovers the concatenated-batch mean.
        let index_of = |step: usize| (step * replicas + replica) % train_batches.len();
        // Micro-batch pipelining: the input tensor for step t+1 is
        // prepared inside step t's overlap window (after the backward
        // pass's gradient sends are posted, before the local optimizer
        // step), so forward setup rides the tail of the gradient
        // sum-reduce instead of serializing after it.
        let mut next_x: Option<Tensor<f32>> = (rank == root && start < cfg.steps)
            .then(|| train_batches[index_of(start)].images_as::<f32>());
        for step in start..cfg.steps {
            comm.fault_step(step as u64)?;
            let timer = Timer::start();
            let batch = &train_batches[index_of(step)];
            let x = next_x.take();
            let prefetch_idx = index_of(step + 1);
            let want_prefetch = rank == root && step + 1 < cfg.steps;
            let (loss, acc) = train_step_hybrid(
                &net,
                &mut state,
                comm,
                root,
                x,
                &batch.labels,
                &mut opt,
                &mut dp,
                &mut || {
                    next_x = want_prefetch
                        .then(|| train_batches[prefetch_idx].images_as::<f32>());
                },
            )?;
            if rank == 0 {
                log.push(StepRecord {
                    step,
                    loss,
                    accuracy: acc,
                    step_time_s: timer.elapsed_s(),
                });
            }
            maybe_checkpoint(cfg, world, rank, step + 1, &state, &opt)?;
        }
        // Held-out evaluation (forward only). Every replica runs the same
        // eval batches — replicas are synchronised copies, so this keeps
        // all ranks collectively in step — and replica 0's root counts.
        let mut correct = 0usize;
        let mut total = 0usize;
        for batch in &eval_batches {
            let x = (rank == root).then(|| batch.images_as::<f32>());
            let logits = net.forward(&mut state, comm, x, false)?;
            if rank == 0 {
                let logits = logits.expect("root holds logits");
                correct += count_correct(&logits, &batch.labels);
                total += batch.labels.len();
            }
        }
        let eval_acc = if total > 0 {
            Some(correct as f64 / total as f64)
        } else {
            None
        };
        // Surface the comm engine's overlap counters and this rank
        // thread's scratch-arena reuse counters on the metric log. The
        // arena is thread-local, so these are exactly the allocations the
        // rank-0 coordinator thread's kernels performed. Every rank hands
        // its fault/health counters back for the per-rank rollup.
        let cs = comm.stats();
        if rank == 0 {
            log.set_comm_stats(&cs);
            log.set_fault_stats(&cs.faults);
            log.set_scratch_stats(&crate::memory::scratch_stats::<f32>());
            log.set_gemm_pool_stats(&crate::nn::native::gemm::gemm_pool_stats());
            log.set_tensor_storage_stats(&crate::tensor::tensor_storage_stats());
            log.set_dp_meta(replicas, dp_overlap(), dp.bucket_count());
        }
        Ok((log, state.param_count(), eval_acc, cs.faults))
    })?;

    let params_per_rank: Vec<usize> = per_rank.iter().map(|(_, p, _, _)| *p).collect();
    let fault_stats: Vec<FaultStats> = per_rank.iter().map(|(_, _, _, fs)| *fs).collect();
    let (mut log, _, eval_accuracy, _) = per_rank.into_iter().next().expect("rank 0 result");
    for (r, fs) in fault_stats.iter().enumerate() {
        log.set_fault_stats_for(r, fs);
    }
    let quarter = (cfg.steps / 4).max(1);
    Ok(TrainReport {
        final_accuracy: log.recent_accuracy(quarter),
        final_loss: log.recent_loss(quarter),
        params_per_rank,
        world,
        eval_accuracy,
        log,
    })
}

/// [`train`] with the layer tape cut into `cfg.stages` pipeline stages
/// (the `cfg.stages > 1` branch).
///
/// The world is `replicas × stages` ranks
/// ([`HybridTopology::with_stages`] with a single-rank model grid);
/// replica `k`'s stage `s` lives on world rank `k·S + s`. Each step,
/// every replica's pipeline streams its `micro_batches` micro-batches
/// through the stages on the 1F1B schedule ([`Pipeline::run_step`]),
/// the DP ring averages each stage's gradients across replicas inside
/// the last micro-batch's backward, and each rank then steps its
/// stage-local Adam state. Step records come from replica 0's last
/// stage (where the loss lives); engine/arena counters from rank 0; the
/// per-stage `pp_*` schedule stats from replica 0's stage ranks.
fn train_pipeline(cfg: &TrainConfig) -> Result<TrainReport> {
    let stages = cfg.stages;
    let m = cfg.micro_batches;
    let replicas = cfg.replicas;
    let topo = HybridTopology::with_stages(replicas, stages, 1)?;
    let world = topo.world();
    let micro = cfg.batch / (replicas * m);
    let data = SyntheticMnist::new(cfg.seed ^ 0xDA7A, cfg.dataset);
    let train_batches = data.batches(micro);
    if train_batches.is_empty() {
        return Err(Error::Config("dataset produced no full batches".into()));
    }
    let eval_data = SyntheticMnist::new(cfg.seed ^ 0xE7A1, (cfg.batch * 4).max(256));
    let eval_batches = eval_data.batches(micro);
    let model_cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };
    // Replica 0's last stage holds the logits and the loss.
    let loss_rank = stages - 1;
    let fault_plan = planned_faults(cfg)?;
    if cfg.preflight_check {
        crate::analysis::preflight(cfg)?;
    }

    let per_rank = Cluster::run(world, |comm| {
        comm.pool_reserve(PIPELINE_POOL_DEPTH);
        let rank = comm.rank();
        let replica = topo.replica_of(rank);
        let base = topo.replica_base(replica);
        let kernels = kernels_for(cfg.backend, &cfg.artifacts_dir)?;
        let (net, plan) = lenet5_pipeline::<f32>(&model_cfg, kernels, stages, base)?;
        // Compute layers keep their unstaged seed offsets, so every
        // replica's staged tape initialises bit-identically to the plain
        // sequential network.
        let mut state = net.init(rank, cfg.seed)?;
        let mut opt = Adam::new(cfg.lr);
        if let Some(p) = fault_plan.clone() {
            comm.set_fault_plan(Some(p));
        }
        let start = maybe_resume(cfg, world, rank, &mut state, &mut opt)?;
        let mut dp = DataParallel::<f32>::for_rank(&topo, rank, DP_TAG_BASE);
        let mut pipe = Pipeline::new(plan, rank, m)?;
        let stage = pipe.stage();
        let mut log = MetricLog::new();
        log.set_meta("layout", "PipelineSequential");
        log.set_meta("backend", format!("{:?}", cfg.backend));
        log.set_meta("batch", cfg.batch);
        log.set_meta("lr", cfg.lr);
        // Micro-batch j of step t on replica k is global micro-batch
        // (t·R + k)·m + j: together the replicas' pipelines consume
        // exactly step t's full batch, so the engine's 1/m scaling times
        // the DP ring's 1/R recovers the concatenated-batch mean.
        let len = train_batches.len();
        let index_of = move |step: usize, j: usize| ((step * replicas + replica) * m + j) % len;
        for step in start..cfg.steps {
            comm.fault_step(step as u64)?;
            let timer = Timer::start();
            let mut input = |k: usize| {
                (stage == 0).then(|| train_batches[index_of(step, k)].images_as::<f32>())
            };
            let mut loss_fn = |k: usize, logits: Tensor<f32>| {
                let labels = &train_batches[index_of(step, k)].labels;
                let (l, probs) = cross_entropy_forward(&logits, labels)?;
                let acc = count_correct(&logits, labels) as f64 / labels.len() as f64;
                Ok((l, acc, cross_entropy_backward(&probs, labels)))
            };
            let (loss, acc) =
                pipe.run_step(&net, &mut state, comm, &mut input, &mut loss_fn, &mut dp)?;
            dp.finish(comm, &mut state)?;
            opt.step(&mut state)?;
            // Weight updates are stage-local; the barrier closes the step
            // epoch so no stage runs ahead into the next step's sends
            // while a peer still drains this one's.
            comm.barrier();
            if rank == loss_rank {
                log.push(StepRecord {
                    step,
                    loss,
                    accuracy: acc,
                    step_time_s: timer.elapsed_s(),
                });
            }
            maybe_checkpoint(cfg, world, rank, step + 1, &state, &opt)?;
        }
        // Held-out evaluation: micro-batch-sized forwards through the
        // stage chain; replica 0's last stage counts.
        let mut correct = 0usize;
        let mut total = 0usize;
        for batch in &eval_batches {
            let x = (stage == 0).then(|| batch.images_as::<f32>());
            let logits = pipe.run_forward(&net, &mut state, comm, x)?;
            if rank == loss_rank {
                let logits = logits.expect("last stage holds logits");
                correct += count_correct(&logits, &batch.labels);
                total += batch.labels.len();
            }
        }
        let eval_acc = (total > 0).then(|| correct as f64 / total as f64);
        let cs = comm.stats();
        if rank == 0 {
            log.set_comm_stats(&cs);
            log.set_fault_stats(&cs.faults);
            log.set_scratch_stats(&crate::memory::scratch_stats::<f32>());
            log.set_gemm_pool_stats(&crate::nn::native::gemm::gemm_pool_stats());
            log.set_tensor_storage_stats(&crate::tensor::tensor_storage_stats());
            log.set_dp_meta(replicas, dp_overlap(), dp.bucket_count());
        }
        Ok((log, state.param_count(), eval_acc, *pipe.stats(), cs.faults))
    })?;

    let params_per_rank: Vec<usize> = per_rank.iter().map(|(_, p, _, _, _)| *p).collect();
    // Roll the per-rank logs up: rank 0 carries the engine/arena
    // counters, the loss rank the step records, replica 0's stage ranks
    // the per-stage schedule stats, and every rank its fault counters.
    let stage_stats: Vec<PipelineStats> = (0..stages).map(|s| per_rank[s].3).collect();
    let fault_stats: Vec<FaultStats> = per_rank.iter().map(|(_, _, _, _, fs)| *fs).collect();
    let eval_accuracy = per_rank[loss_rank].2;
    let steps = per_rank[loss_rank].0.steps.clone();
    let mut log = per_rank.into_iter().next().expect("rank 0 result").0;
    log.steps = steps;
    for (r, fs) in fault_stats.iter().enumerate() {
        log.set_fault_stats_for(r, fs);
    }
    log.set_pp_meta(stages, m, pp_overlap());
    let mut bubble_sum = 0.0;
    let mut queue = 0usize;
    for (s, st) in stage_stats.iter().enumerate() {
        log.set_pp_stage_stats(s, st.idle_s, st.bubble_fraction(), st.max_in_flight);
        bubble_sum += st.bubble_fraction();
        queue = queue.max(st.max_in_flight);
    }
    log.set_pp_rollup(bubble_sum / stages as f64, analytic_bubble(stages, m), queue);
    let quarter = (cfg.steps / 4).max(1);
    Ok(TrainReport {
        final_accuracy: log.recent_accuracy(quarter),
        final_loss: log.recent_loss(quarter),
        params_per_rank,
        world,
        eval_accuracy,
        log,
    })
}

/// One synchronous training step (collective). Returns (loss, accuracy)
/// as seen by the loss root; other ranks return (0, 0).
pub fn train_step(
    net: &crate::autograd::Network<f32>,
    state: &mut NetworkState<f32>,
    comm: &mut Comm,
    batch: &Batch,
    opt: &mut Adam<f32>,
) -> Result<(f64, f64)> {
    let x = (comm.rank() == 0).then(|| batch.images_as::<f32>());
    train_step_prepared(net, state, comm, x, &batch.labels, opt, &mut || {})
}

/// [`train_step`] with a pre-built input tensor and an overlap hook.
///
/// `overlap` runs after the backward pass returns on this rank and before
/// the (purely local) optimizer step. The gradient sum-reduce sends are
/// posted eagerly inside `backward`, and on every rank but the reduce
/// roots the final backward actions *are* sends — so work done in the
/// hook (the training loop prepares the next micro-batch's input there)
/// proceeds while peers are still draining those gradient messages.
pub fn train_step_prepared(
    net: &crate::autograd::Network<f32>,
    state: &mut NetworkState<f32>,
    comm: &mut Comm,
    x: Option<Tensor<f32>>,
    labels: &[usize],
    opt: &mut Adam<f32>,
    overlap: &mut dyn FnMut(),
) -> Result<(f64, f64)> {
    // A single-member DP group is inert: pure model parallelism.
    let mut dp = DataParallel::new(CommGroup::new(vec![comm.rank()])?, DP_TAG_BASE);
    train_step_hybrid(net, state, comm, 0, x, labels, opt, &mut dp, overlap)
}

/// One synchronous hybrid training step: distributed forward, loss at the
/// replica's `root`, distributed backward with the DP engine's
/// `on_layer_done` hook riding each layer's adjoint (ready gradient
/// buckets start their ring all-reduce while deeper layers' δw/δb GEMMs
/// still run), then [`DataParallel::finish`] and the local optimizer
/// step. Returns (loss, accuracy) as seen by `root`; other ranks return
/// (0, 0).
pub fn train_step_hybrid(
    net: &crate::autograd::Network<f32>,
    state: &mut NetworkState<f32>,
    comm: &mut Comm,
    root: usize,
    x: Option<Tensor<f32>>,
    labels: &[usize],
    opt: &mut Adam<f32>,
    dp: &mut DataParallel<f32>,
    overlap: &mut dyn FnMut(),
) -> Result<(f64, f64)> {
    let logits = net.forward(state, comm, x, true)?;
    let mut dlogits: Option<Tensor<f32>> = None;
    let mut loss = 0f64;
    let mut acc = 0f64;
    if comm.rank() == root {
        let logits = logits.ok_or_else(|| Error::Autograd("root lost the logits".into()))?;
        let (l, probs) = cross_entropy_forward(&logits, labels)?;
        loss = l;
        acc = count_correct(&logits, labels) as f64 / labels.len() as f64;
        dlogits = Some(cross_entropy_backward(&probs, labels));
    }
    state.zero_grads();
    net.backward_with_hook(state, comm, dlogits, &mut |layer, st, c| {
        dp.on_layer_done(c, st, layer)
    })?;
    overlap();
    dp.finish(comm, state)?;
    opt.step(state)?;
    Ok((loss, acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_sequential_training_learns() {
        let cfg = TrainConfig {
            batch: 16,
            steps: 30,
            dataset: 512,
            distributed: false,
            log_every: 10,
            ..TrainConfig::default()
        };
        let report = train(&cfg).unwrap();
        assert_eq!(report.world, 1);
        assert_eq!(report.log.steps.len(), 30);
        // loss must drop substantially from ln(10) ≈ 2.30
        let first = report.log.steps[0].loss;
        assert!(first > 1.8, "initial loss {first}");
        assert!(
            report.final_loss < first * 0.8,
            "no learning: {first} -> {}",
            report.final_loss
        );
    }

    #[test]
    fn short_data_parallel_training_runs() {
        // Sequential model grid × 2 replicas: pure data parallelism.
        let cfg = TrainConfig {
            batch: 16,
            steps: 8,
            dataset: 256,
            distributed: false,
            replicas: 2,
            ..TrainConfig::default()
        };
        let report = train(&cfg).unwrap();
        assert_eq!(report.world, 2);
        assert_eq!(report.params_per_rank.len(), 2);
        // Replicas hold identical full copies of the model.
        assert_eq!(report.params_per_rank[0], report.params_per_rank[1]);
        assert!(report.log.steps.iter().all(|s| s.loss.is_finite()));
        assert_eq!(report.log.meta["dp_replicas"], "2");
    }

    #[test]
    fn short_pipeline_training_learns() {
        // Sequential tape cut into 2 stages, 4 micro-batches per step.
        let cfg = TrainConfig {
            batch: 16,
            steps: 30,
            dataset: 512,
            distributed: false,
            stages: 2,
            micro_batches: 4,
            ..TrainConfig::default()
        };
        let report = train(&cfg).unwrap();
        assert_eq!(report.world, 2);
        assert_eq!(report.log.steps.len(), 30);
        let first = report.log.steps[0].loss;
        assert!(first > 1.8, "initial loss {first}");
        assert!(
            report.final_loss < first * 0.8,
            "no learning: {first} -> {}",
            report.final_loss
        );
        assert_eq!(report.log.meta["pp_stages"], "2");
        assert_eq!(report.log.meta["pp_micro_batches"], "4");
        assert!(report.log.meta.contains_key("pp_bubble_measured"));
        assert!(report.log.meta.contains_key("pp_stage1_idle_s"));
    }

    #[test]
    fn short_pipeline_data_parallel_training_runs() {
        // 2 replicas × 2 stages: all three parallel axes' machinery at
        // once (the model grid degenerate).
        let cfg = TrainConfig {
            batch: 16,
            steps: 6,
            dataset: 256,
            distributed: false,
            replicas: 2,
            stages: 2,
            micro_batches: 2,
            ..TrainConfig::default()
        };
        let report = train(&cfg).unwrap();
        assert_eq!(report.world, 4);
        assert_eq!(report.params_per_rank.len(), 4);
        // Replica 1's stages mirror replica 0's.
        assert_eq!(report.params_per_rank[0], report.params_per_rank[2]);
        assert_eq!(report.params_per_rank[1], report.params_per_rank[3]);
        assert!(report.log.steps.iter().all(|s| s.loss.is_finite()));
        assert_eq!(report.log.meta["dp_replicas"], "2");
        assert_eq!(report.log.meta["pp_stages"], "2");
    }

    #[test]
    fn short_distributed_training_runs() {
        let cfg = TrainConfig {
            batch: 8,
            steps: 10,
            dataset: 128,
            distributed: true,
            ..TrainConfig::default()
        };
        let report = train(&cfg).unwrap();
        assert_eq!(report.world, 4);
        assert_eq!(report.params_per_rank.len(), 4);
        // Table-1 totals: worker 0 holds conv params + affine shards
        assert!(report.params_per_rank[0] > report.params_per_rank[3]);
        assert!(report.log.steps.iter().all(|s| s.loss.is_finite()));
    }
}
pub mod suites;
