//! Seeded, deterministic fault injection for the comm engine.
//!
//! A [`FaultPlan`] describes misbehaviour to inject at the
//! message-delivery seam of [`super::Comm`]: every message is judged
//! *once*, on the receiving endpoint, the moment it is pulled off the
//! channel — before sequencing, parking, or matching. The verdict is a
//! pure hash of `(plan seed, rule index, receiver rank, source rank, tag,
//! wire sequence number)`, so a plan is **fully deterministic**: the same
//! plan over the same traffic injects exactly the same faults on every
//! run, on any machine, regardless of thread timing. (Wall-clock effects
//! — how long a delayed message is held — vary; *which* messages are
//! delayed, dropped, duplicated, reordered, or truncated does not.)
//!
//! Plans come from two places:
//!
//! * the `PALLAS_FAULT_PLAN` environment variable, read once per
//!   [`super::Cluster::run`] and installed on every endpoint — how the CI
//!   chaos legs run the whole test suite under faults; or
//! * programmatically via [`super::Comm::set_fault_plan`], which is what
//!   the fault-tolerance tests and [`crate::config::TrainConfig::fault_plan`]
//!   use (per-endpoint, immune to cross-test env races).
//!
//! ## Plan grammar
//!
//! A plan is a `;`-separated list of clauses:
//!
//! ```text
//! seed=7; retry_ms=10; delay:p=0.1,ms=2; dup:p=0.05; drop:p=0.02,tag=40
//! ```
//!
//! * `seed=N` — the plan's hash seed (default 0).
//! * `retry_ms=N` — override the endpoints' retry/straggler threshold
//!   (`0` disables retries); `timeout_ms=N` likewise overrides the fatal
//!   receive deadline (`0` = no deadline). Both mirror the
//!   `PALLAS_RETRY_TIMEOUT_MS` / `PALLAS_RECV_TIMEOUT_MS` variables so a
//!   plan is self-contained: a chaos plan that drops messages can bound
//!   its own recovery latency.
//! * `kill:rank=R,step=K` — [`super::Comm::fault_step`] returns an error
//!   on rank `R` at step `K` (the coordinator checks at the top of every
//!   training step — the kill-at-step-k harness for checkpoint/resume).
//! * fault rules `kind:arg=value,...` with kinds `delay`, `drop`, `dup`
//!   (or `duplicate`), `reorder`, `truncate` and arguments:
//!   `p` (probability in `[0,1]`, default 1), `src`/`dst`/`tag` (match
//!   filters; absent = match any), `ms` (hold time for delay/reorder).
//!
//! Rules are evaluated in plan order; the **first matching rule whose
//! probability draw fires wins** — later rules never see that message.
//! Whitespace around clauses, keys, and values is ignored.

use crate::error::{Error, Result};
use crate::util::rng::SplitMix64;

/// Environment variable carrying a fault plan for every endpoint of every
/// [`super::Cluster::run`] in the process (the CI chaos-leg hook).
pub const FAULT_PLAN_ENV: &str = "PALLAS_FAULT_PLAN";

/// The kinds of misbehaviour a [`FaultRule`] can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Hold the message back for `ms` milliseconds before delivering it.
    Delay,
    /// Withhold the message entirely; it is recovered only by the
    /// receiver's bounded retransmit path (a simulated retransmission).
    Drop,
    /// Deliver the message twice; the sequence layer must suppress the
    /// second copy.
    Duplicate,
    /// Hold the message briefly (default 1 ms) so later traffic on the
    /// same stream overtakes it — exercises the out-of-order resequencer.
    Reorder,
    /// Deliver a corrupted copy (wire bytes with the tail cut off); the
    /// pristine payload is recoverable through the retransmit path when
    /// the receiver's length check rejects the corrupted copy.
    Truncate,
}

/// One fault rule: a kind, a firing probability, and optional match
/// filters over source rank, destination (receiver) rank, and tag.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    /// What to inject.
    pub kind: FaultKind,
    /// Probability the rule fires for a matching message, in `[0, 1]`.
    pub p: f64,
    /// Only messages from this source rank (any if `None`).
    pub src: Option<usize>,
    /// Only messages delivered to this receiver rank (any if `None`).
    pub dst: Option<usize>,
    /// Only messages with this tag (any if `None`).
    pub tag: Option<u64>,
    /// Hold duration in milliseconds (delay/reorder).
    pub ms: u64,
}

impl FaultRule {
    fn matches(&self, dst: usize, src: usize, tag: u64) -> bool {
        self.src.is_none_or(|s| s == src)
            && self.dst.is_none_or(|d| d == dst)
            && self.tag.is_none_or(|t| t == tag)
    }
}

/// A scheduled rank death: [`super::Comm::fault_step`] errors on `rank`
/// when the coordinator reaches `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillRule {
    /// World rank to kill.
    pub rank: usize,
    /// Training step at which it dies.
    pub step: u64,
}

/// The verdict for one message (see [`FaultPlan::decide`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Deliver normally.
    Deliver,
    /// Hold for the given number of milliseconds.
    Delay(u64),
    /// Withhold until retransmitted.
    Drop,
    /// Deliver twice.
    Duplicate,
    /// Hold briefly so the stream reorders.
    Reorder(u64),
    /// Deliver a corrupted copy, keep the pristine one for retransmit.
    Truncate,
}

/// A complete, seeded fault plan (see the module docs for the grammar).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Hash seed for the per-message probability draws.
    pub seed: u64,
    /// Fault rules, evaluated in order; first firing match wins.
    pub rules: Vec<FaultRule>,
    /// Scheduled rank deaths.
    pub kills: Vec<KillRule>,
    /// Optional retry/straggler threshold override in milliseconds
    /// (`Some(0)` disables retries).
    pub retry_ms: Option<u64>,
    /// Optional fatal receive-deadline override in milliseconds
    /// (`Some(0)` = no deadline).
    pub timeout_ms: Option<u64>,
}

impl FaultPlan {
    /// Whether the plan injects or kills anything at all.
    pub fn is_active(&self) -> bool {
        !self.rules.is_empty() || !self.kills.is_empty()
    }

    /// Judge one message delivered to receiver `dst` from `src` with
    /// `tag` and wire sequence number `seq`. Pure: the same arguments
    /// always produce the same verdict.
    pub fn decide(&self, dst: usize, src: usize, tag: u64, seq: u64) -> Verdict {
        for (i, rule) in self.rules.iter().enumerate() {
            if !rule.matches(dst, src, tag) {
                continue;
            }
            let draw = if rule.p >= 1.0 {
                0.0
            } else {
                // One independent, reproducible stream per
                // (rule, message) pair: hash the identifying tuple into
                // a SplitMix64 seed and take a single uniform draw.
                let mut h = self
                    .seed
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(i as u64);
                h ^= (dst as u64).wrapping_mul(0xA24BAED4963EE407);
                h ^= (src as u64).wrapping_mul(0x9FB21C651E98DF25);
                h ^= tag.wrapping_mul(0xD1B54A32D192ED03);
                h ^= seq.wrapping_mul(0x2545F4914F6CDD1D);
                SplitMix64::new(h).next_f64()
            };
            if draw < rule.p {
                return match rule.kind {
                    FaultKind::Delay => Verdict::Delay(rule.ms),
                    FaultKind::Drop => Verdict::Drop,
                    FaultKind::Duplicate => Verdict::Duplicate,
                    FaultKind::Reorder => Verdict::Reorder(rule.ms),
                    FaultKind::Truncate => Verdict::Truncate,
                };
            }
        }
        Verdict::Deliver
    }

    /// Whether the plan kills `rank` at `step`.
    pub fn kills_at(&self, rank: usize, step: u64) -> bool {
        self.kills.iter().any(|k| k.rank == rank && k.step == step)
    }

    /// Parse the plan grammar (see the module docs). Errors name the
    /// offending clause so a typo'd plan fails loudly instead of silently
    /// injecting nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (head, args) = match clause.split_once(':') {
                Some((h, a)) => (h.trim(), a),
                None => {
                    // No `:` — a top-level `key=value` clause.
                    let (k, v) = clause.split_once('=').ok_or_else(|| {
                        Error::Config(format!(
                            "fault plan clause `{clause}`: expected `kind:args` or `key=value`"
                        ))
                    })?;
                    match k.trim() {
                        "seed" => plan.seed = parse_num(clause, v)?,
                        "retry_ms" => plan.retry_ms = Some(parse_num(clause, v)?),
                        "timeout_ms" => plan.timeout_ms = Some(parse_num(clause, v)?),
                        other => {
                            return Err(Error::Config(format!(
                                "fault plan clause `{clause}`: unknown setting `{other}`"
                            )))
                        }
                    }
                    continue;
                }
            };
            if head == "kill" {
                let mut rank = None;
                let mut step = None;
                for (k, v) in parse_args(clause, args)? {
                    match k.as_str() {
                        "rank" => rank = Some(parse_num(clause, &v)? as usize),
                        "step" => step = Some(parse_num(clause, &v)?),
                        _ => {
                            return Err(Error::Config(format!(
                                "fault plan clause `{clause}`: unknown kill argument `{k}`"
                            )))
                        }
                    }
                }
                match (rank, step) {
                    (Some(rank), Some(step)) => plan.kills.push(KillRule { rank, step }),
                    _ => {
                        return Err(Error::Config(format!(
                            "fault plan clause `{clause}`: kill needs rank= and step="
                        )))
                    }
                }
                continue;
            }
            let kind = match head {
                "delay" => FaultKind::Delay,
                "drop" => FaultKind::Drop,
                "dup" | "duplicate" => FaultKind::Duplicate,
                "reorder" => FaultKind::Reorder,
                "truncate" => FaultKind::Truncate,
                other => {
                    return Err(Error::Config(format!(
                        "fault plan clause `{clause}`: unknown fault kind `{other}`"
                    )))
                }
            };
            let mut rule = FaultRule {
                kind,
                p: 1.0,
                src: None,
                dst: None,
                tag: None,
                ms: match kind {
                    FaultKind::Delay => 2,
                    FaultKind::Reorder => 1,
                    _ => 0,
                },
            };
            for (k, v) in parse_args(clause, args)? {
                match k.as_str() {
                    "p" => {
                        let p: f64 = v.parse().map_err(|_| {
                            Error::Config(format!(
                                "fault plan clause `{clause}`: bad probability `{v}`"
                            ))
                        })?;
                        if !(0.0..=1.0).contains(&p) {
                            return Err(Error::Config(format!(
                                "fault plan clause `{clause}`: probability {p} outside [0, 1]"
                            )));
                        }
                        rule.p = p;
                    }
                    "src" => rule.src = Some(parse_num(clause, &v)? as usize),
                    "dst" => rule.dst = Some(parse_num(clause, &v)? as usize),
                    "tag" => rule.tag = Some(parse_num(clause, &v)?),
                    "ms" => rule.ms = parse_num(clause, &v)?,
                    _ => {
                        return Err(Error::Config(format!(
                            "fault plan clause `{clause}`: unknown argument `{k}`"
                        )))
                    }
                }
            }
            plan.rules.push(rule);
        }
        Ok(plan)
    }
}

fn parse_num(clause: &str, v: &str) -> Result<u64> {
    v.trim()
        .parse::<u64>()
        .map_err(|_| Error::Config(format!("fault plan clause `{clause}`: bad number `{v}`")))
}

fn parse_args(clause: &str, args: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for pair in args.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        match pair.split_once('=') {
            Some((k, v)) => out.push((k.trim().to_string(), v.trim().to_string())),
            None => {
                return Err(Error::Config(format!(
                    "fault plan clause `{clause}`: expected `key=value`, got `{pair}`"
                )))
            }
        }
    }
    Ok(out)
}

/// The fault plan configured by `PALLAS_FAULT_PLAN`, if any. A malformed
/// plan warns on stderr and injects nothing (env knobs must never turn a
/// typo into changed behaviour); programmatic plans go through
/// [`FaultPlan::parse`] and error instead.
pub fn configured_fault_plan() -> Option<FaultPlan> {
    let raw = std::env::var(FAULT_PLAN_ENV).ok()?;
    if raw.trim().is_empty() {
        return None;
    }
    match FaultPlan::parse(&raw) {
        Ok(plan) => plan.is_active().then_some(plan),
        Err(e) => {
            eprintln!("warning: ignoring malformed {FAULT_PLAN_ENV}: {e}");
            None
        }
    }
}

/// Per-endpoint injection/recovery counters, surfaced as `fault_*`
/// MetricLog keys and on [`super::CommStats::faults`]. All of the
/// `injected_*` counters are receiver-side (faults are judged at
/// delivery); the retry/straggler counters are the endpoint's own
/// watchdog observations.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct FaultStats {
    /// Messages held back by a delay rule.
    pub injected_delays: usize,
    /// Messages withheld by a drop rule (recovered via retransmit).
    pub injected_drops: usize,
    /// Messages delivered twice by a duplicate rule.
    pub injected_dups: usize,
    /// Messages held back by a reorder rule.
    pub injected_reorders: usize,
    /// Messages corrupted by a truncate rule.
    pub injected_truncations: usize,
    /// Duplicate deliveries suppressed by the wire-sequence layer.
    pub dups_suppressed: usize,
    /// Retry-threshold firings while blocked on a receive (each one
    /// re-examines the stream and, when something is withheld, triggers a
    /// retransmission).
    pub retries: usize,
    /// Withheld payloads recovered through the retransmit path (dropped
    /// messages re-delivered, truncated payloads replaced by their
    /// pristine copy).
    pub retransmits: usize,
    /// Blocked receives that outlived at least one retry threshold — the
    /// straggler count of the progress watchdog.
    pub stragglers: usize,
    /// Abandoned-request messages swept on arrival (their payloads
    /// dropped so registered buffers return to their sender's pool).
    pub abandoned_swept: usize,
    /// Longest single blocked receive observed, in seconds.
    pub max_stall_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "seed=7; retry_ms=10; timeout_ms=0; delay:p=0.1,ms=20; dup:p=0.5,src=1,dst=0; \
             drop:tag=40; reorder:; truncate:p=0.25; kill:rank=2,step=5",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.retry_ms, Some(10));
        assert_eq!(plan.timeout_ms, Some(0));
        assert_eq!(plan.rules.len(), 5);
        assert_eq!(plan.rules[0].kind, FaultKind::Delay);
        assert_eq!(plan.rules[0].ms, 20);
        assert_eq!(plan.rules[1].kind, FaultKind::Duplicate);
        assert_eq!((plan.rules[1].src, plan.rules[1].dst), (Some(1), Some(0)));
        assert_eq!(plan.rules[2].tag, Some(40));
        assert_eq!(plan.rules[2].p, 1.0);
        assert_eq!(plan.rules[3].kind, FaultKind::Reorder);
        assert_eq!(plan.rules[3].ms, 1);
        assert_eq!(plan.rules[4].kind, FaultKind::Truncate);
        assert_eq!(plan.kills, vec![KillRule { rank: 2, step: 5 }]);
        assert!(plan.is_active());
        assert!(plan.kills_at(2, 5));
        assert!(!plan.kills_at(2, 4));
        assert!(!plan.kills_at(1, 5));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode:p=1").is_err());
        assert!(FaultPlan::parse("delay").is_err());
        assert!(FaultPlan::parse("delay:p=2.0").is_err());
        assert!(FaultPlan::parse("delay:p=oops").is_err());
        assert!(FaultPlan::parse("delay:wat=1").is_err());
        assert!(FaultPlan::parse("kill:rank=1").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
        assert!(FaultPlan::parse("delay:p").is_err());
        // The empty plan parses and is inert.
        let empty = FaultPlan::parse("").unwrap();
        assert!(!empty.is_active());
        assert_eq!(empty, FaultPlan::default());
    }

    #[test]
    fn decide_is_deterministic_and_seed_sensitive() {
        let plan = FaultPlan::parse("seed=3;drop:p=0.5").unwrap();
        let verdicts: Vec<Verdict> = (0..64).map(|s| plan.decide(0, 1, 9, s)).collect();
        let again: Vec<Verdict> = (0..64).map(|s| plan.decide(0, 1, 9, s)).collect();
        assert_eq!(verdicts, again, "verdicts must be pure");
        let drops = verdicts.iter().filter(|v| **v == Verdict::Drop).count();
        assert!(drops > 5 && drops < 60, "p=0.5 over 64 draws, got {drops}");
        // A different seed reshuffles the outcome pattern.
        let other = FaultPlan::parse("seed=4;drop:p=0.5").unwrap();
        let reseeded: Vec<Verdict> = (0..64).map(|s| other.decide(0, 1, 9, s)).collect();
        assert_ne!(verdicts, reseeded);
    }

    #[test]
    fn first_matching_rule_wins_and_filters_apply() {
        let plan = FaultPlan::parse("drop:tag=1;delay:tag=1,ms=9;dup:src=2").unwrap();
        assert_eq!(plan.decide(0, 1, 1, 0), Verdict::Drop);
        assert_eq!(plan.decide(0, 2, 3, 0), Verdict::Duplicate);
        assert_eq!(plan.decide(0, 1, 3, 0), Verdict::Deliver);
        // p=1 rules fire on every matching message.
        for seq in 0..8 {
            assert_eq!(plan.decide(5, 1, 1, seq), Verdict::Drop);
        }
    }

    #[test]
    fn inert_env_values_are_ignored() {
        // configured_fault_plan reads the process env; with the variable
        // unset in the test harness it must report no plan. (Value-bearing
        // cases are covered via FaultPlan::parse above — mutating the
        // process env would race other tests.)
        if std::env::var(FAULT_PLAN_ENV).is_err() {
            assert!(configured_fault_plan().is_none());
        }
    }
}
