//! Plan-capture mode: record the communication schedule, not the math.
//!
//! The paper's framing — every data-movement operation is a *linear
//! operator* with a hand-derived adjoint — means the entire cross-rank
//! message schedule of a model/topology is a finite, analyzable object.
//! This module is the recording half of the static verifier in
//! [`crate::analysis`]: a [`Comm`](super::Comm) endpoint switched into
//! capture mode ([`Comm::plan_begin`](super::Comm::plan_begin)) logs every
//! send post, receive post, completion, timeout, and barrier as a
//! [`PlanEvent`], each stamped with the *scope path* of the primitive that
//! issued it and the [`Phase`] (forward / backward / data-parallel) the
//! harness declared. The resulting per-rank event logs are joined into a
//! plan graph and checked for endpoint mismatches, tag collisions,
//! deadlocks, adjoint-duality violations, and pool leaks — before any
//! kernel math runs.
//!
//! Scope attribution is RAII: every `DistLinearOp::forward`/`adjoint`
//! opens a [`PlanScope`] naming itself, so nested compositions (an
//! all-reduce built from a sum-reduce and a broadcast, a gather built
//! from a scatter's adjoint) produce hierarchical paths like
//! `AllReduce(B∘R)/B[root 0, {0,1,2,3}]`. Consecutive duplicate labels
//! are collapsed when the path is built, so an operator that implements
//! its adjoint by re-entering its own forward keys its traffic to the
//! same path in both directions. When no capture is active the guard is
//! an `Option` check — the production hot path never allocates a label.

use std::sync::{Arc, Mutex};

/// Which logical phase of a plan capture an event belongs to.
///
/// The phase is declared by the capture harness
/// ([`Comm::plan_phase`](super::Comm::plan_phase)), not derived from the
/// scope: an operator's forward and adjoint share one scope path (tag
/// attribution must not split them) while the duality analysis separates
/// their volumes by phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Before the harness declared a phase (setup traffic, if any).
    Setup,
    /// The forward plan F.
    Forward,
    /// The backward plan, expected to be Fᵀ (Eq. 13's static shadow).
    Backward,
    /// Data-parallel gradient averaging (self-adjoint ring schedules;
    /// excluded from the duality pairing).
    DataParallel,
}

/// One recorded communication event on an endpoint.
///
/// `seq` is the per-stream sequence number the engine itself assigns:
/// send seq `k` on stream `(src, dst, tag)` matches receive-post seq `k`
/// on the same stream (both counters start at 0 and advance together),
/// which is exactly the nonovertaking rule the endpoint-matching analysis
/// pairs events by.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanEvent {
    /// A posted send (recording rank is the source).
    Send {
        /// Destination world rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Wire sequence number on the `(dst, tag)` stream.
        seq: u64,
        /// Wire-equivalent payload volume.
        bytes: usize,
        /// Element type name (`"bytes"` for raw wire payloads).
        dtype: &'static str,
        /// Whether the payload travels in a registered pool buffer that
        /// must return to this sender (the pool-balance analysis).
        pooled: bool,
    },
    /// A posted receive (recording rank is the destination).
    RecvPost {
        /// Source world rank.
        src: usize,
        /// Message tag.
        tag: u64,
        /// Request sequence number on the `(src, tag)` stream.
        seq: u64,
        /// Element type the receiver expects.
        dtype: &'static str,
    },
    /// A completed receive (recording rank is the destination).
    RecvComplete {
        /// Source world rank.
        src: usize,
        /// Message tag.
        tag: u64,
        /// Request sequence number.
        seq: u64,
        /// Wire-equivalent volume actually received.
        bytes: usize,
    },
    /// A receive that hit the fatal deadline or a disconnect — the
    /// blocked-forever marker the deadlock analysis builds its wait-for
    /// graph from.
    RecvTimeout {
        /// Source world rank the endpoint was blocked on.
        src: usize,
        /// Message tag.
        tag: u64,
        /// Request sequence number.
        seq: u64,
    },
    /// A full-world barrier. `index` counts this endpoint's barriers;
    /// ranks must agree on the count and interleave sends/receives
    /// consistently around each index.
    Barrier {
        /// This endpoint's barrier ordinal (0-based).
        index: usize,
    },
}

/// A [`PlanEvent`] plus its scope-path and phase attribution.
#[derive(Debug, Clone)]
pub struct ScopedEvent {
    /// `/`-joined path of [`PlanScope`] labels active at record time,
    /// consecutive duplicates collapsed. Empty when no scope was open.
    pub scope: String,
    /// Phase declared by the capture harness at record time.
    pub phase: Phase,
    /// The event itself.
    pub event: PlanEvent,
}

/// Recorder attached to a [`Comm`](super::Comm) in plan-capture mode.
///
/// Shared behind `Arc<Mutex<..>>` so RAII scope guards can outlive the
/// borrow of the endpoint that created them (a guard is held *across*
/// `&mut Comm` calls) and so `barrier(&self)` can record through a shared
/// reference.
#[derive(Debug, Default)]
pub struct PlanRecorder {
    scopes: Vec<String>,
    phase: Option<Phase>,
    barriers: usize,
    events: Vec<ScopedEvent>,
}

impl PlanRecorder {
    /// Fresh recorder in [`Phase::Setup`] with no open scopes.
    pub fn new() -> Self {
        PlanRecorder::default()
    }

    /// Declare the phase subsequent events belong to.
    pub fn set_phase(&mut self, phase: Phase) {
        self.phase = Some(phase);
    }

    /// Open a scope (innermost last).
    pub fn push_scope(&mut self, label: String) {
        self.scopes.push(label);
    }

    /// Close the innermost scope.
    pub fn pop_scope(&mut self) {
        self.scopes.pop();
    }

    /// The current scope path: open scopes joined with `/`, consecutive
    /// duplicate labels collapsed (an operator whose adjoint re-enters
    /// its own forward must key both directions to one path).
    pub fn scope_path(&self) -> String {
        let mut parts: Vec<&str> = Vec::with_capacity(self.scopes.len());
        for s in &self.scopes {
            if parts.last() != Some(&s.as_str()) {
                parts.push(s.as_str());
            }
        }
        parts.join("/")
    }

    /// Record `event` under the current scope path and phase.
    pub fn record(&mut self, event: PlanEvent) {
        self.events.push(ScopedEvent {
            scope: self.scope_path(),
            phase: self.phase.unwrap_or(Phase::Setup),
            event,
        });
    }

    /// Allocate the next barrier ordinal.
    pub fn next_barrier(&mut self) -> usize {
        let i = self.barriers;
        self.barriers += 1;
        i
    }

    /// Drain the recorded events.
    pub fn take_events(&mut self) -> Vec<ScopedEvent> {
        std::mem::take(&mut self.events)
    }
}

/// RAII scope guard: pushes a label on the active recorder (if any) at
/// construction, pops it on drop — so `?` early returns unwind scopes
/// correctly. The label closure runs only when a capture is active;
/// production runs pay one `Option` check and never build the string.
///
/// The guard holds a clone of the recorder handle, **not** a borrow of
/// the endpoint, so the creating `&mut Comm` stays free for the
/// operator body:
///
/// ```ignore
/// fn forward(&self, comm: &mut Comm, x: ...) -> Result<...> {
///     let _scope = PlanScope::enter(comm, || self.name());
///     // ... comm.isend_*/irecv/wait as usual ...
/// }
/// ```
pub struct PlanScope(Option<Arc<Mutex<PlanRecorder>>>);

impl PlanScope {
    /// Open a scope named by `label` on `comm`'s recorder, if capturing.
    pub fn enter(comm: &super::Comm, label: impl FnOnce() -> String) -> Self {
        match comm.plan_handle() {
            Some(h) => {
                if let Ok(mut g) = h.lock() {
                    g.push_scope(label());
                }
                PlanScope(Some(h))
            }
            None => PlanScope(None),
        }
    }
}

impl Drop for PlanScope {
    fn drop(&mut self) {
        if let Some(h) = &self.0 {
            if let Ok(mut g) = h.lock() {
                g.pop_scope();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_path_collapses_consecutive_duplicates() {
        let mut r = PlanRecorder::new();
        r.push_scope("outer".into());
        r.push_scope("AllReduce".into());
        r.push_scope("AllReduce".into()); // adjoint re-entering forward
        r.push_scope("B".into());
        assert_eq!(r.scope_path(), "outer/AllReduce/B");
        r.pop_scope();
        r.pop_scope();
        assert_eq!(r.scope_path(), "outer/AllReduce");
    }

    #[test]
    fn scope_path_keeps_nonconsecutive_duplicates() {
        let mut r = PlanRecorder::new();
        r.push_scope("a".into());
        r.push_scope("b".into());
        r.push_scope("a".into());
        assert_eq!(r.scope_path(), "a/b/a");
    }

    #[test]
    fn events_carry_phase_and_scope() {
        let mut r = PlanRecorder::new();
        let index = r.next_barrier();
        r.record(PlanEvent::Barrier { index });
        r.set_phase(Phase::Forward);
        r.push_scope("op".into());
        r.record(PlanEvent::Send {
            dst: 1,
            tag: 7,
            seq: 0,
            bytes: 64,
            dtype: "f32",
            pooled: false,
        });
        let evs = r.take_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].phase, Phase::Setup);
        assert_eq!(evs[0].scope, "");
        assert_eq!(evs[1].phase, Phase::Forward);
        assert_eq!(evs[1].scope, "op");
        assert!(r.take_events().is_empty());
    }
}
