//! The socket backend: TCP or Unix-domain streams, one full-duplex
//! connection per rank pair, so a [`Cluster`](super::Cluster) spans OS
//! processes.
//!
//! # Bootstrap
//!
//! Rank 0 is the coordinator. It listens at the coordinator address
//! (`PALLAS_COORD_ADDR`, or an ephemeral address when the whole world
//! lives in one process); every other rank
//!
//! 1. binds its own data listener (Unix: `<coord>.r<rank>`; TCP: an
//!    ephemeral port),
//! 2. retry-connects to the coordinator and sends a `Hello` frame
//!    announcing its rank and data-listener address,
//! 3. receives the complete address book back from rank 0 once all
//!    `world - 1` hellos are in,
//! 4. connects to every *lower* rank `0 < j < rank` (announcing itself
//!    with a `Hello`) and accepts one connection from every higher rank.
//!
//! The streams to/from rank 0 **are** the coordinator connections — no
//! separate data listener for rank 0 — and sequential connect-then-accept
//! cannot deadlock because every listener is bound before any connect and
//! the OS accept backlog holds early arrivals. All listeners are dropped
//! (and Unix socket files unlinked) once the mesh is complete.
//!
//! # Data path
//!
//! `send` serializes the body into the frame format of
//! [`transport`](super::transport) and drops it — a pooled payload's
//! registered buffer returns to its sender's pool the moment the bytes
//! are staged (staging-ownership guarantee #2). Self-sends bypass the
//! wire and keep their typed body, preserving the zero-copy path rank-
//! locally. One detached reader thread per peer turns inbound frames
//! into engine messages (data) or barrier announcements (control); a
//! reader exits on EOF, and once every reader is gone a blocked receive
//! reports [`Arrival::Disconnected`].
//!
//! # Barrier
//!
//! Epoch-counted: entering barrier `e`, a rank sends a `Barrier` frame
//! with `tag = e` to every peer and waits for `world - 1` epoch-`e`
//! announcements. A fast peer may already announce `e + 1` before this
//! rank has collected all of `e` (announcements travel on the same FIFO
//! streams as data, so nothing later than `e + 1` can exist yet); those
//! early arrivals are banked for the next epoch.

use super::transport::{
    encode_frame_header, read_frame, wire_bytes_of, Arrival, Body, FrameKind, Message, Transport,
    TransportKind, DTYPE_OPAQUE,
};
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long a rank keeps retrying its connection to the coordinator (or
/// a peer's data listener) before giving up.
const CONNECT_DEADLINE: Duration = Duration::from_secs(10);

/// Pause between connection retries during bootstrap.
const CONNECT_RETRY: Duration = Duration::from_millis(10);

/// Ceiling on a single barrier round-trip. Barrier frames bypass the
/// engine's fault injection, so this only fires when a peer is truly
/// wedged or dead.
const BARRIER_DEADLINE: Duration = Duration::from_secs(120);

/// Slice width for chunked blocking receives — how often a blocked
/// receive re-checks whether every reader thread has exited.
const LIVENESS_SLICE: Duration = Duration::from_millis(50);

// ---------------------------------------------------------------------
// Stream / listener abstraction over the two address families
// ---------------------------------------------------------------------

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    /// The local IP of a TCP stream — what a peer's advertised data
    /// address must be reachable at.
    fn local_ip(&self) -> Option<String> {
        match self {
            Stream::Tcp(s) => s.local_addr().ok().map(|a| a.ip().to_string()),
            Stream::Unix(_) => None,
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    /// Keeps the bound path so drop can unlink the socket file.
    Unix(UnixListener, String),
}

impl Listener {
    fn bind(kind: TransportKind, addr: &str) -> Result<Listener> {
        match kind {
            TransportKind::Tcp => Ok(Listener::Tcp(TcpListener::bind(addr)?)),
            TransportKind::Unix => {
                // A stale socket file from a crashed run blocks the bind.
                let _ = std::fs::remove_file(addr);
                Ok(Listener::Unix(UnixListener::bind(addr)?, addr.to_string()))
            }
            TransportKind::Channel => Err(Error::Config(
                "channel transport has no socket listener".into(),
            )),
        }
    }

    fn accept(&self) -> Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Stream::Tcp(s)
            }
            Listener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                Stream::Unix(s)
            }
        })
    }

    /// The ephemeral port a TCP listener landed on.
    fn tcp_port(&self) -> Option<u16> {
        match self {
            Listener::Tcp(l) => l.local_addr().ok().map(|a| a.port()),
            Listener::Unix(..) => None,
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

fn connect_with_retry(kind: TransportKind, addr: &str) -> Result<Stream> {
    let deadline = Instant::now() + CONNECT_DEADLINE;
    loop {
        let attempt = match kind {
            TransportKind::Tcp => TcpStream::connect(addr).map(|s| {
                let _ = s.set_nodelay(true);
                Stream::Tcp(s)
            }),
            TransportKind::Unix => UnixStream::connect(addr).map(Stream::Unix),
            TransportKind::Channel => {
                return Err(Error::Config("channel transport has no socket peer".into()))
            }
        };
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(Error::Comm(format!(
                    "could not reach {addr} within {CONNECT_DEADLINE:?}: {e}"
                )))
            }
            Err(_) => std::thread::sleep(CONNECT_RETRY),
        }
    }
}

// ---------------------------------------------------------------------
// Bootstrap handshake frames
// ---------------------------------------------------------------------

fn send_hello(s: &mut Stream, src: usize, payload: &[u8]) -> Result<()> {
    let h = encode_frame_header(FrameKind::Hello, DTYPE_OPAQUE, src, 0, 0, payload.len());
    s.write_all(&h)?;
    s.write_all(payload)?;
    s.flush()?;
    Ok(())
}

fn recv_hello(s: &mut Stream) -> Result<(usize, Vec<u8>)> {
    match read_frame(s)? {
        Some((h, p)) if h.kind == FrameKind::Hello => Ok((h.src, p)),
        Some((h, _)) => Err(Error::Protocol(format!(
            "expected a hello frame during bootstrap, got {:?}",
            h.kind
        ))),
        None => Err(Error::Protocol(
            "stream closed during the bootstrap handshake".into(),
        )),
    }
}

// ---------------------------------------------------------------------
// The transport
// ---------------------------------------------------------------------

/// A socket-backed [`Transport`] over TCP or Unix-domain stream
/// connections (one per rank pair, built by a rank-0 coordinator
/// bootstrap; see [`crate::comm`]'s module docs for the contract).
pub struct SocketTransport {
    rank: usize,
    world: usize,
    kind: TransportKind,
    /// Write halves, indexed by peer rank (`None` at `self.rank`).
    peers: Vec<Option<Stream>>,
    /// Kept for self-sends, which stay typed (zero-copy) and skip the
    /// wire entirely.
    inbox_tx: Sender<Message>,
    inbox_rx: Receiver<Message>,
    /// Barrier epochs announced by peers, routed here by the readers.
    ctrl_rx: Receiver<u64>,
    /// The epoch the *next* barrier call will synchronize on.
    barrier_epoch: u64,
    /// Banked early barrier announcements (per epoch) — a fast peer may
    /// announce epoch `e + 1` while this rank is still collecting `e`.
    early: HashMap<u64, usize>,
    /// Reader threads still attached to a live peer stream. Zero (with
    /// `world > 1`) means nothing can ever arrive again.
    live_readers: Arc<AtomicUsize>,
}

/// A coordinator listener bound *before* any rank starts connecting —
/// how an in-process socket cluster avoids both address races and
/// pick-a-free-port guesswork (TCP binds port 0 and the kernel chooses).
pub(crate) struct ReservedCoord {
    addr: String,
    listener: Mutex<Option<Listener>>,
}

/// Distinguishes concurrent in-process socket clusters (unit tests run
/// many) so their Unix socket paths never collide.
static COORD_SERIAL: AtomicU64 = AtomicU64::new(0);

impl SocketTransport {
    /// Bind a fresh ephemeral coordinator listener for an in-process
    /// cluster launch.
    pub(crate) fn reserve_coord(kind: TransportKind) -> Result<ReservedCoord> {
        match kind {
            TransportKind::Tcp => {
                let listener = Listener::bind(kind, "127.0.0.1:0")?;
                let port = listener.tcp_port().ok_or_else(|| {
                    Error::Comm("coordinator listener has no local port".into())
                })?;
                Ok(ReservedCoord {
                    addr: format!("127.0.0.1:{port}"),
                    listener: Mutex::new(Some(listener)),
                })
            }
            TransportKind::Unix => {
                let serial = COORD_SERIAL.fetch_add(1, Ordering::Relaxed);
                let path = std::env::temp_dir().join(format!(
                    "pallas-coord-{}-{serial}.sock",
                    std::process::id()
                ));
                let addr = path.to_string_lossy().into_owned();
                let listener = Listener::bind(kind, &addr)?;
                Ok(ReservedCoord {
                    addr,
                    listener: Mutex::new(Some(listener)),
                })
            }
            TransportKind::Channel => Err(Error::Config(
                "channel transport has no coordinator address".into(),
            )),
        }
    }

    /// Join a cluster whose coordinator listener was prebound by
    /// [`reserve_coord`](SocketTransport::reserve_coord) (the in-process
    /// [`Cluster::run_on`](super::Cluster::run_on) path).
    pub(crate) fn connect_reserved(
        kind: TransportKind,
        world: usize,
        rank: usize,
        coord: &ReservedCoord,
    ) -> Result<SocketTransport> {
        if rank == 0 {
            let listener = coord
                .listener
                .lock()
                .map_err(|_| Error::Comm("coordinator listener lock poisoned".into()))?
                .take()
                .ok_or_else(|| Error::Comm("coordinator listener already taken".into()))?;
            Self::bootstrap_rank0(kind, world, listener)
        } else {
            Self::bootstrap_peer(kind, world, rank, &coord.addr)
        }
    }

    /// Join a cluster at an explicit coordinator address (the
    /// multi-process path: every process calls this once with its rank).
    /// Rank 0 binds the coordinator listener at `coord_addr`; everyone
    /// else retry-connects to it.
    pub fn connect(
        kind: TransportKind,
        world: usize,
        rank: usize,
        coord_addr: &str,
    ) -> Result<SocketTransport> {
        if world == 0 {
            return Err(Error::Comm("world size must be >= 1".into()));
        }
        if rank >= world {
            return Err(Error::Comm(format!(
                "rank {rank} out of range (world {world})"
            )));
        }
        if rank == 0 {
            let listener = Listener::bind(kind, coord_addr)?;
            Self::bootstrap_rank0(kind, world, listener)
        } else {
            Self::bootstrap_peer(kind, world, rank, coord_addr)
        }
    }

    /// Rank 0: accept every other rank's hello on the coordinator
    /// listener, then broadcast the address book. The accepted streams
    /// *are* rank 0's data links.
    fn bootstrap_rank0(
        kind: TransportKind,
        world: usize,
        listener: Listener,
    ) -> Result<SocketTransport> {
        let mut peers: Vec<Option<Stream>> = (0..world).map(|_| None).collect();
        let mut book: Vec<Option<String>> = vec![None; world];
        for _ in 1..world {
            let mut s = listener.accept()?;
            let (src, addr_bytes) = recv_hello(&mut s)?;
            if src == 0 || src >= world || peers[src].is_some() {
                return Err(Error::Protocol(format!(
                    "bootstrap hello from invalid or duplicate rank {src} (world {world})"
                )));
            }
            let addr = String::from_utf8(addr_bytes).map_err(|_| {
                Error::Protocol(format!("rank {src} announced a non-UTF-8 listener address"))
            })?;
            book[src] = Some(addr);
            peers[src] = Some(s);
        }
        // Address book: "rank addr" per line, ranks 1..world.
        let book_text = book
            .iter()
            .enumerate()
            .skip(1)
            .map(|(r, a)| format!("{r} {}", a.as_deref().expect("all hellos collected")))
            .collect::<Vec<_>>()
            .join("\n");
        for peer in peers.iter_mut().flatten() {
            send_hello(peer, 0, book_text.as_bytes())?;
        }
        drop(listener); // unlinks the Unix coordinator socket file
        Ok(Self::assemble(kind, world, 0, peers))
    }

    /// Ranks > 0: announce to the coordinator, receive the address book,
    /// then mesh — connect to every lower rank, accept from every higher.
    fn bootstrap_peer(
        kind: TransportKind,
        world: usize,
        rank: usize,
        coord_addr: &str,
    ) -> Result<SocketTransport> {
        // Bind the data listener before anything else so peers that learn
        // our address can connect immediately (the accept backlog holds
        // them until we get there).
        let (listener, mut advertised) = match kind {
            TransportKind::Unix => {
                let addr = format!("{coord_addr}.r{rank}");
                (Listener::bind(kind, &addr)?, addr)
            }
            TransportKind::Tcp => {
                let l = Listener::bind(kind, "0.0.0.0:0")?;
                let port = l
                    .tcp_port()
                    .ok_or_else(|| Error::Comm("data listener has no local port".into()))?;
                // The reachable IP is filled in after the coordinator
                // connection tells us which interface faces it.
                (l, format!(":{port}"))
            }
            TransportKind::Channel => {
                return Err(Error::Config("channel transport has no socket mesh".into()))
            }
        };

        let mut coord = connect_with_retry(kind, coord_addr)?;
        if let Some(ip) = coord.local_ip() {
            advertised = format!("{ip}{advertised}");
        }
        send_hello(&mut coord, rank, advertised.as_bytes())?;
        let (src, book_bytes) = recv_hello(&mut coord)?;
        if src != 0 {
            return Err(Error::Protocol(format!(
                "address book came from rank {src}, expected the coordinator"
            )));
        }
        let book_text = String::from_utf8(book_bytes)
            .map_err(|_| Error::Protocol("address book is not UTF-8".into()))?;
        let mut book: Vec<Option<String>> = vec![None; world];
        for line in book_text.lines() {
            let (r, addr) = line.split_once(' ').ok_or_else(|| {
                Error::Protocol(format!("malformed address-book line {line:?}"))
            })?;
            let r: usize = r
                .parse()
                .map_err(|_| Error::Protocol(format!("malformed address-book rank {r:?}")))?;
            if r == 0 || r >= world {
                return Err(Error::Protocol(format!(
                    "address book names rank {r}, outside 1..{world}"
                )));
            }
            book[r] = Some(addr.to_string());
        }

        let mut peers: Vec<Option<Stream>> = (0..world).map(|_| None).collect();
        peers[0] = Some(coord);
        // Connect to every lower rank (they accept), announcing who we are.
        for (j, addr) in book.iter().enumerate().take(rank).skip(1) {
            let addr = addr.as_deref().ok_or_else(|| {
                Error::Protocol(format!("address book is missing rank {j}"))
            })?;
            let mut s = connect_with_retry(kind, addr)?;
            send_hello(&mut s, rank, &[])?;
            peers[j] = Some(s);
        }
        // Accept one connection from every higher rank.
        for _ in rank + 1..world {
            let mut s = listener.accept()?;
            let (src, _) = recv_hello(&mut s)?;
            if src <= rank || src >= world || peers[src].is_some() {
                return Err(Error::Protocol(format!(
                    "mesh hello from invalid or duplicate rank {src} (accepting at rank {rank})"
                )));
            }
            peers[src] = Some(s);
        }
        drop(listener); // unlinks the Unix data socket file
        Ok(Self::assemble(kind, world, rank, peers))
    }

    /// Wire up the inbox and spawn one detached reader thread per peer.
    fn assemble(
        kind: TransportKind,
        world: usize,
        rank: usize,
        mut peers: Vec<Option<Stream>>,
    ) -> SocketTransport {
        let (inbox_tx, inbox_rx) = channel::<Message>();
        let (ctrl_tx, ctrl_rx) = channel::<u64>();
        let live_readers = Arc::new(AtomicUsize::new(0));
        for (peer, slot) in peers.iter_mut().enumerate() {
            let Some(stream) = slot else { continue };
            let read_half = stream
                .try_clone()
                .unwrap_or_else(|e| panic!("rank {rank}: cannot clone stream to {peer}: {e}"));
            live_readers.fetch_add(1, Ordering::SeqCst);
            let tx = inbox_tx.clone();
            let ctrl = ctrl_tx.clone();
            let live = live_readers.clone();
            std::thread::spawn(move || {
                reader_loop(rank, peer, read_half, tx, ctrl);
                live.fetch_sub(1, Ordering::SeqCst);
            });
        }
        SocketTransport {
            rank,
            world,
            kind,
            peers,
            inbox_tx,
            inbox_rx,
            ctrl_rx,
            barrier_epoch: 0,
            early: HashMap::new(),
            live_readers,
        }
    }

    /// Whether nothing can ever arrive again: every peer's reader has
    /// exited (EOF or error) and the inbox is drained. Never true for a
    /// single-rank world, where self-sends are the only traffic — the
    /// same semantics the channel backend gets from holding its own
    /// sender.
    fn all_peers_gone(&self) -> bool {
        self.world > 1 && self.live_readers.load(Ordering::SeqCst) == 0
    }
}

/// Turn inbound frames into engine messages (data) and barrier epochs
/// (control) until the peer hangs up. Protocol violations are loud but
/// non-fatal to the process: the reader warns, drops the connection, and
/// the engine sees the peer as disconnected.
fn reader_loop(
    rank: usize,
    peer: usize,
    mut stream: Stream,
    tx: Sender<Message>,
    ctrl: Sender<u64>,
) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some((h, payload))) => match h.kind {
                FrameKind::Data => {
                    let delivered = tx.send(Message {
                        src: h.src,
                        tag: h.tag,
                        seq: h.seq,
                        body: Body::Bytes(payload),
                    });
                    if delivered.is_err() {
                        return; // endpoint dropped; stop reading
                    }
                }
                FrameKind::Barrier => {
                    if ctrl.send(h.tag).is_err() {
                        return;
                    }
                }
                FrameKind::Hello => {
                    eprintln!(
                        "warning: rank {rank} got a bootstrap hello from rank {peer} \
                         after the mesh was up; dropping the connection"
                    );
                    return;
                }
            },
            Ok(None) => return, // clean EOF: peer closed
            Err(e) => {
                eprintln!(
                    "warning: rank {rank} dropping connection to rank {peer}: {e}"
                );
                return;
            }
        }
    }
}

impl Transport for SocketTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn kind(&self) -> &'static str {
        self.kind.name()
    }

    fn send(&mut self, dst: usize, msg: Message) -> Result<()> {
        if dst == self.rank {
            // Self-sends skip the wire and stay typed: the zero-copy Arc
            // path and pooled-buffer cycle survive rank-locally.
            return self
                .inbox_tx
                .send(msg)
                .map_err(|_| Error::Comm(format!("rank {dst} disconnected")));
        }
        let stream = match self.peers[dst].as_mut() {
            Some(s) => s,
            None => return Err(Error::Comm(format!("rank {dst} disconnected"))),
        };
        // Serialize, ship, drop: once the bytes are staged the body (and
        // any pooled registration it holds) goes home to the sender's
        // pool — staging-ownership guarantee #2.
        let payload = wire_bytes_of(&msg.body);
        let header = encode_frame_header(
            FrameKind::Data,
            msg.body.dtype_tag(),
            msg.src,
            msg.tag,
            msg.seq,
            payload.len(),
        );
        let shipped = stream
            .write_all(&header)
            .and_then(|()| stream.write_all(&payload))
            .and_then(|()| stream.flush());
        if let Err(e) = shipped {
            self.peers[dst] = None;
            return Err(Error::Comm(format!("rank {dst} disconnected ({e})")));
        }
        Ok(())
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.inbox_rx.try_recv().ok()
    }

    fn recv_deadline(&mut self, timeout: Duration) -> Arrival {
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Arrival::Timeout;
            }
            let slice = LIVENESS_SLICE.min(deadline - now);
            match self.inbox_rx.recv_timeout(slice) {
                Ok(msg) => return Arrival::Message(msg),
                Err(RecvTimeoutError::Timeout) => {
                    if self.all_peers_gone() {
                        // Late pushes race the reader's exit: drain first.
                        return match self.inbox_rx.try_recv() {
                            Ok(msg) => Arrival::Message(msg),
                            Err(_) => Arrival::Disconnected,
                        };
                    }
                }
                // Unreachable while we hold inbox_tx, but harmless.
                Err(RecvTimeoutError::Disconnected) => return Arrival::Disconnected,
            }
        }
    }

    fn recv_blocking(&mut self) -> Arrival {
        loop {
            match self.inbox_rx.recv_timeout(LIVENESS_SLICE) {
                Ok(msg) => return Arrival::Message(msg),
                Err(RecvTimeoutError::Timeout) => {
                    if self.all_peers_gone() {
                        return match self.inbox_rx.try_recv() {
                            Ok(msg) => Arrival::Message(msg),
                            Err(_) => Arrival::Disconnected,
                        };
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Arrival::Disconnected,
            }
        }
    }

    fn barrier(&mut self) -> Result<()> {
        let epoch = self.barrier_epoch;
        self.barrier_epoch += 1;
        if self.world == 1 {
            return Ok(());
        }
        let announce = encode_frame_header(FrameKind::Barrier, DTYPE_OPAQUE, self.rank, epoch, 0, 0);
        for dst in 0..self.world {
            if dst == self.rank {
                continue;
            }
            let stream = self.peers[dst].as_mut().ok_or_else(|| {
                Error::Comm(format!("barrier with rank {dst} already disconnected"))
            })?;
            stream
                .write_all(&announce)
                .and_then(|()| stream.flush())
                .map_err(|e| Error::Comm(format!("barrier send to rank {dst} failed: {e}")))?;
        }
        let mut seen = self.early.remove(&epoch).unwrap_or(0);
        let deadline = Instant::now() + BARRIER_DEADLINE;
        while seen < self.world - 1 {
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Comm(format!(
                    "rank {} barrier epoch {epoch} timed out with {seen} of {} peers",
                    self.rank,
                    self.world - 1
                )));
            }
            match self.ctrl_rx.recv_timeout(deadline - now) {
                Ok(e) if e == epoch => seen += 1,
                Ok(e) => {
                    // A fast peer already announced a later epoch (FIFO
                    // streams bound this to exactly epoch + 1); bank it.
                    *self.early.entry(e).or_insert(0) += 1;
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Comm(format!(
                        "rank {} barrier epoch {epoch}: control channel closed",
                        self.rank
                    )))
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Cluster, TransportKind};

    fn ring_over(kind: TransportKind) {
        let results = Cluster::run_on(kind, 4, |comm| {
            let next = (comm.rank() + 1) % comm.size();
            let prev = (comm.rank() + comm.size() - 1) % comm.size();
            comm.send_slice::<f64>(next, 1, &[comm.rank() as f64])?;
            let got = comm.recv_vec::<f64>(prev, 1)?;
            assert_eq!(comm.transport_kind(), kind.name());
            Ok(got[0])
        })
        .unwrap();
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn unix_ring_pass() {
        ring_over(TransportKind::Unix);
    }

    #[test]
    fn tcp_ring_pass() {
        ring_over(TransportKind::Tcp);
    }

    #[test]
    fn unix_single_rank_world() {
        let r = Cluster::run_on(TransportKind::Unix, 1, |comm| {
            comm.send_slice::<f64>(0, 9, &[2.5])?;
            let got = comm.recv_vec::<f64>(0, 9)?;
            comm.barrier();
            Ok(got[0])
        })
        .unwrap();
        assert_eq!(r, vec![2.5]);
    }

    #[test]
    fn unix_barrier_epochs_stay_aligned() {
        // Repeated barriers with unbalanced work between them exercise
        // the early-announcement banking.
        Cluster::run_on(TransportKind::Unix, 3, |comm| {
            for round in 0..20u64 {
                if comm.rank() == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(round % 3));
                }
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn unix_mixed_dtypes_and_tags() {
        let results = Cluster::run_on(TransportKind::Unix, 2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f32>(1, 5, &[1.5, -2.5])?;
                comm.send_slice::<f64>(1, 6, &[3.25])?;
                Ok(0.0)
            } else {
                let f = comm.recv_vec::<f32>(0, 5)?;
                let d = comm.recv_vec::<f64>(0, 6)?;
                Ok(f64::from(f[0]) + f64::from(f[1]) + d[0])
            }
        })
        .unwrap();
        assert_eq!(results[1], 1.5 - 2.5 + 3.25);
    }

    #[test]
    fn unix_out_of_order_tags() {
        let results = Cluster::run_on(TransportKind::Unix, 2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 2, &[20.0])?;
                comm.send_slice::<f64>(1, 1, &[10.0])?;
                Ok(0.0)
            } else {
                let a = comm.recv_vec::<f64>(0, 1)?[0];
                let b = comm.recv_vec::<f64>(0, 2)?[0];
                Ok(a * 1000.0 + b)
            }
        })
        .unwrap();
        assert_eq!(results[1], 10020.0);
    }
}
