//! The in-process channel backend: the default [`Transport`] and the
//! test substrate.
//!
//! One unbounded `mpsc` channel per rank plus a shared [`Barrier`]. A
//! [`Message`] passes through **untouched** — a typed body's `Arc` moves
//! across threads without any serialize/deserialize round-trip, which is
//! what makes the zero-copy payload path and the receiver-returns-to-
//! sender pool cycle possible (staging-ownership guarantee #2 of the
//! [`Transport`] contract, in its in-process reading). FIFO per pair is
//! inherited from `mpsc`; disconnection is channel disconnection.

use super::transport::{Arrival, Message, Transport};
use crate::error::{Error, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// In-process transport over `mpsc` channels: the default backend and
/// the test substrate. Messages pass through untouched, preserving the
/// zero-copy typed payload path (see [`crate::comm`]'s module docs).
pub struct ChannelTransport {
    rank: usize,
    world: usize,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    barrier: Arc<Barrier>,
}

impl ChannelTransport {
    /// Build the full mesh for a `world`-rank in-process cluster: every
    /// endpoint can reach every other (and itself). The constructor's
    /// sender handles are dropped before the endpoints are handed out,
    /// so channel disconnection propagates exactly when the *ranks*
    /// drop their endpoints.
    pub fn mesh(world: usize) -> Vec<ChannelTransport> {
        let mut senders = Vec::with_capacity(world);
        let mut inboxes = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(rx);
        }
        let barrier = Arc::new(Barrier::new(world));
        inboxes
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| ChannelTransport {
                rank,
                world,
                senders: senders.clone(),
                inbox,
                barrier: barrier.clone(),
            })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn kind(&self) -> &'static str {
        "channel"
    }

    fn send(&mut self, dst: usize, msg: Message) -> Result<()> {
        self.senders[dst]
            .send(msg)
            .map_err(|_| Error::Comm(format!("rank {dst} disconnected")))
    }

    fn try_recv(&mut self) -> Option<Message> {
        match self.inbox.try_recv() {
            Ok(msg) => Some(msg),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    fn recv_deadline(&mut self, timeout: Duration) -> Arrival {
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => Arrival::Message(msg),
            Err(RecvTimeoutError::Timeout) => Arrival::Timeout,
            Err(RecvTimeoutError::Disconnected) => Arrival::Disconnected,
        }
    }

    fn recv_blocking(&mut self) -> Arrival {
        match self.inbox.recv() {
            Ok(msg) => Arrival::Message(msg),
            Err(_) => Arrival::Disconnected,
        }
    }

    fn barrier(&mut self) -> Result<()> {
        self.barrier.wait();
        Ok(())
    }
}
