//! The [`Transport`] trait — the seam between the request engine and the
//! bytes (or buffers) that actually move — plus the wire format a real
//! backend speaks.
//!
//! ## The contract
//!
//! [`Comm`](super::Comm) owns exactly one boxed `Transport` and drives it
//! from four call sites: posting a send, the nonblocking inbox pump, the
//! blocking waits (with and without a deadline), and the full-world
//! barrier. Everything else — sequence numbers, resequencing, duplicate
//! suppression, retry/retransmit clocks, fault injection, plan capture,
//! the registered buffer pool — lives *above* this trait in the engine,
//! so every backend inherits the ARQ layer unchanged. A backend must
//! guarantee exactly three things:
//!
//! 1. **Per-pair FIFO.** Messages from one sender to one receiver are
//!    delivered in the order they were sent. (TCP and Unix streams give
//!    this per connection; the in-process backend gets it from `mpsc`.)
//!    The engine's sequence numbers *verify* this and repair violations,
//!    but a backend that reorders wholesale will spend its life in the
//!    out-of-order buffer.
//! 2. **Staging ownership.** `send` consumes the [`Message`]. A backend
//!    that serializes (the socket backend) must drop the body after
//!    encoding so a pooled payload's registered buffer returns to its
//!    sender's pool immediately — exactly the wire-format staging
//!    discipline. A backend that forwards in-process (the channel
//!    backend) must pass the body through untouched so the zero-copy
//!    `Arc` path and the receiver-returns-to-sender pool cycle survive.
//! 3. **Delivery-seam transparency.** Arrivals are handed to the engine
//!    raw, exactly once per wire delivery, in arrival order. The fault
//!    injector ([`super::faults`]) judges each arrival *after* the
//!    transport produces it, which is what lets the same seeded plan
//!    drive both the in-process backend and a socket conformance run.
//!
//! ## Wire format
//!
//! On a byte-stream backend every message travels as one **frame**:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "PLLS"
//!      4     1  version (currently 1)
//!      5     1  kind    (0 data, 1 barrier, 2 hello)
//!      6     1  dtype   (0 opaque bytes, 4 f32, 8 f64 — element wire size)
//!      7     1  reserved (must be 0)
//!      8     4  src rank, little-endian u32
//!     12     8  tag, little-endian u64
//!     20     8  sequence number, little-endian u64
//!     28     8  payload length, little-endian u64
//!     36     …  payload
//! ```
//!
//! The payload of a data frame is the crate's length-checked typed
//! encoding (8-byte element count + little-endian elements, see
//! `parse_wire`) — the format [`Comm::set_wire_format`] has always
//! produced in-process now graduates to the actual on-the-wire encoding.
//! A frame with a bad magic, an unknown kind or dtype, a non-zero
//! reserved byte, or a **newer version** than this build speaks is
//! rejected with [`Error::Protocol`] naming the mismatch; a stream that
//! ends mid-frame is a protocol error too (clean EOF is only legal at a
//! frame boundary).
//!
//! [`Comm::set_wire_format`]: super::Comm::set_wire_format

use crate::error::{Error, Result};
use crate::tensor::Scalar;
use std::any::Any;
use std::cell::Cell;
use std::io::Read;
use std::sync::Arc;
use std::time::Duration;

pub(crate) type AnyArc = Arc<dyn Any + Send + Sync>;

// ---------------------------------------------------------------------
// Message payloads
// ---------------------------------------------------------------------

/// A typed, `Arc`-backed payload: the zero-copy path.
pub(crate) struct TypedBody {
    pub(crate) len: usize,
    pub(crate) wire_size: usize,
    pub(crate) data: AnyArc,
    pub(crate) to_wire: fn(&AnyArc) -> Vec<u8>,
}

/// Message payload: zero-copy typed buffer, or raw wire bytes.
pub(crate) enum Body {
    Bytes(Vec<u8>),
    Typed(TypedBody),
}

impl Body {
    /// Size this payload occupies (or would occupy) on the wire — used for
    /// the traffic counters so both paths report comparable volumes.
    pub(crate) fn wire_len(&self) -> usize {
        match self {
            Body::Bytes(b) => b.len(),
            Body::Typed(t) => 8 + t.len * t.wire_size,
        }
    }

    /// The frame dtype tag for this payload: the element wire size for
    /// typed bodies, [`DTYPE_OPAQUE`] for raw bytes.
    pub(crate) fn dtype_tag(&self) -> u8 {
        match self {
            Body::Bytes(_) => DTYPE_OPAQUE,
            Body::Typed(t) => t.wire_size as u8,
        }
    }
}

/// A tagged message in flight between two ranks.
///
/// `seq` is the per-`(sender, tag)` wire sequence number the receiving
/// engine resequences on: duplicates are suppressed, reordered arrivals
/// buffered until the gap fills. The engine stamps it before handing the
/// message to the transport; a backend carries it opaquely.
pub struct Message {
    pub(crate) src: usize,
    pub(crate) tag: u64,
    pub(crate) seq: u64,
    pub(crate) body: Body,
}

impl Message {
    /// Sending world rank.
    pub fn src(&self) -> usize {
        self.src
    }

    /// Message tag.
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Per-`(sender, tag)` wire sequence number.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Wire-equivalent payload size in bytes.
    pub fn wire_len(&self) -> usize {
        self.body.wire_len()
    }
}

/// Clone a message body — the fault layer's duplicate injection. Typed
/// bodies clone only the `Arc` (a pooled payload's registration stays
/// shared, so suppression of the copy cannot double-return the buffer).
pub(crate) fn clone_body(b: &Body) -> Body {
    match b {
        Body::Bytes(v) => Body::Bytes(v.clone()),
        Body::Typed(t) => Body::Typed(TypedBody {
            len: t.len,
            wire_size: t.wire_size,
            data: t.data.clone(),
            to_wire: t.to_wire,
        }),
    }
}

/// Render a body as wire bytes (what a serializing backend sends; the
/// fault layer's truncation corrupts a copy of this rendering and the
/// length check catches it on decode).
pub(crate) fn wire_bytes_of(b: &Body) -> Vec<u8> {
    match b {
        Body::Bytes(v) => v.clone(),
        Body::Typed(t) => (t.to_wire)(&t.data),
    }
}

/// Serialize a typed payload into the wire format (header + little-endian
/// elements). Stored as a fn pointer in [`TypedBody`] so a type-erased
/// message can still be rendered as bytes.
pub(crate) fn wire_of<T: Scalar>(data: &AnyArc) -> Vec<u8> {
    let v = data
        .downcast_ref::<Vec<T>>()
        .expect("typed body serializer sees its own element type");
    let mut buf = Vec::with_capacity(8 + v.len() * T::WIRE_SIZE);
    buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
    T::write_bytes(v, &mut buf);
    buf
}

/// Parse a wire-format buffer, enforcing the length check.
pub(crate) fn parse_wire<T: Scalar>(buf: &[u8]) -> Result<Vec<T>> {
    if buf.len() < 8 {
        return Err(Error::Comm("truncated message header".into()));
    }
    let n = u64::from_le_bytes(buf[..8].try_into().unwrap()) as usize;
    let body = &buf[8..];
    if body.len() != n * T::WIRE_SIZE {
        return Err(Error::Comm(format!(
            "message length {} != {} x {} elements",
            body.len(),
            n,
            T::WIRE_SIZE
        )));
    }
    Ok(T::read_bytes(body))
}

// ---------------------------------------------------------------------
// The transport trait
// ---------------------------------------------------------------------

/// Outcome of a blocking receive on a transport.
pub enum Arrival {
    /// A message arrived.
    Message(Message),
    /// The deadline elapsed with nothing to deliver.
    Timeout,
    /// Every peer is gone; nothing will ever arrive again.
    Disconnected,
}

/// A communication backend: moves [`Message`]s between the ranks of one
/// world.
///
/// See the [module docs](self) for the three guarantees a backend must
/// provide (per-pair FIFO, staging ownership, delivery-seam
/// transparency). The engine serializes all calls on one endpoint —
/// `&mut self` everywhere — so a backend needs no internal locking for
/// correctness, only for whatever background reader threads it runs.
pub trait Transport: Send {
    /// This endpoint's world rank.
    fn rank(&self) -> usize;

    /// World size.
    fn world(&self) -> usize;

    /// Backend name for diagnostics (`"channel"`, `"tcp"`, `"unix"`).
    fn kind(&self) -> &'static str;

    /// Ship `msg` to `dst` (already validated to be in range). Must not
    /// block on the receiver; errors mean the peer is unreachable.
    fn send(&mut self, dst: usize, msg: Message) -> Result<()>;

    /// Nonblocking poll: the next arrival if one is already available.
    /// `None` means "nothing right now" *or* "all peers gone" — the
    /// engine's pump treats both as end-of-drain.
    fn try_recv(&mut self) -> Option<Message>;

    /// Block up to `timeout` for the next arrival.
    fn recv_deadline(&mut self, timeout: Duration) -> Arrival;

    /// Block indefinitely for the next arrival (never returns
    /// [`Arrival::Timeout`]).
    fn recv_blocking(&mut self) -> Arrival;

    /// Full-world barrier: returns once every rank has entered it.
    fn barrier(&mut self) -> Result<()>;
}

// ---------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------

/// Which [`Transport`] backend a [`Cluster`](super::Cluster) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process `mpsc` channels (zero-copy; the default and the test
    /// substrate).
    Channel,
    /// TCP sockets — one loopback-or-LAN stream per rank pair.
    Tcp,
    /// Unix-domain sockets — one filesystem-addressed stream per rank
    /// pair.
    Unix,
}

impl TransportKind {
    /// Parse a backend name (the `--transport` flag / `PALLAS_TRANSPORT`
    /// vocabulary).
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.trim() {
            "channel" => Ok(TransportKind::Channel),
            "tcp" => Ok(TransportKind::Tcp),
            "unix" => Ok(TransportKind::Unix),
            other => Err(Error::Config(format!(
                "unknown transport '{other}' (expected channel, tcp, or unix)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
            TransportKind::Unix => "unix",
        }
    }
}

thread_local! {
    static TRANSPORT_OVERRIDE: Cell<Option<TransportKind>> = const { Cell::new(None) };
}

/// The backend [`Cluster::run`](super::Cluster::run) launches on this
/// thread: a live [`TransportGuard`] override wins, then a valid
/// `PALLAS_TRANSPORT` (warn-and-default discipline via
/// [`crate::util::env`]), then [`TransportKind::Channel`].
pub fn default_transport() -> TransportKind {
    if let Some(k) = TRANSPORT_OVERRIDE.with(|c| c.get()) {
        return k;
    }
    match crate::util::env::configured_transport() {
        Some(name) => TransportKind::parse(&name).unwrap_or(TransportKind::Channel),
        None => TransportKind::Channel,
    }
}

/// RAII thread-local backend override: every [`Cluster::run`] issued from
/// this thread while the guard lives uses the given backend. This is how
/// the conformance suites re-run the whole adjoint/chaos machinery over
/// loopback sockets without threading a parameter through every harness,
/// and how `--transport` reaches the plan-capture clusters.
///
/// [`Cluster::run`]: super::Cluster::run
pub struct TransportGuard {
    prev: Option<TransportKind>,
}

impl TransportGuard {
    /// Override the default backend on this thread until drop.
    pub fn set(kind: TransportKind) -> TransportGuard {
        let prev = TRANSPORT_OVERRIDE.with(|c| c.replace(Some(kind)));
        TransportGuard { prev }
    }
}

impl Drop for TransportGuard {
    fn drop(&mut self) {
        TRANSPORT_OVERRIDE.with(|c| c.set(self.prev));
    }
}

// ---------------------------------------------------------------------
// Frame encoding
// ---------------------------------------------------------------------

/// Frame magic: the first four bytes of every frame.
pub const FRAME_MAGIC: [u8; 4] = *b"PLLS";

/// The frame version this build speaks. A peer announcing a higher
/// version is rejected ([`Error::Protocol`]); lower versions do not exist
/// (the format was born at 1), so any other value is garbage.
pub const WIRE_VERSION: u8 = 1;

/// Frame header size in bytes.
pub const FRAME_HEADER_LEN: usize = 36;

/// Dtype tag for opaque byte payloads (control frames, raw
/// `send_bytes` traffic).
pub const DTYPE_OPAQUE: u8 = 0;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// An engine message (tag/seq meaningful, payload = wire encoding).
    Data,
    /// A barrier announcement (tag = barrier epoch, empty payload).
    Barrier,
    /// A bootstrap handshake (payload = address book or listener
    /// address).
    Hello,
}

impl FrameKind {
    fn to_byte(self) -> u8 {
        match self {
            FrameKind::Data => 0,
            FrameKind::Barrier => 1,
            FrameKind::Hello => 2,
        }
    }

    fn from_byte(b: u8) -> Option<FrameKind> {
        match b {
            0 => Some(FrameKind::Data),
            1 => Some(FrameKind::Barrier),
            2 => Some(FrameKind::Hello),
            _ => None,
        }
    }
}

/// A decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// What the frame carries.
    pub kind: FrameKind,
    /// Element wire size of the payload (0 = opaque).
    pub dtype: u8,
    /// Sending world rank.
    pub src: usize,
    /// Message tag (barrier frames: the epoch).
    pub tag: u64,
    /// Wire sequence number (0 for control frames).
    pub seq: u64,
    /// Payload length in bytes.
    pub len: usize,
}

/// Encode a frame header.
pub fn encode_frame_header(
    kind: FrameKind,
    dtype: u8,
    src: usize,
    tag: u64,
    seq: u64,
    len: usize,
) -> [u8; FRAME_HEADER_LEN] {
    let mut h = [0u8; FRAME_HEADER_LEN];
    h[0..4].copy_from_slice(&FRAME_MAGIC);
    h[4] = WIRE_VERSION;
    h[5] = kind.to_byte();
    h[6] = dtype;
    // h[7] reserved, zero
    h[8..12].copy_from_slice(&(src as u32).to_le_bytes());
    h[12..20].copy_from_slice(&tag.to_le_bytes());
    h[20..28].copy_from_slice(&seq.to_le_bytes());
    h[28..36].copy_from_slice(&(len as u64).to_le_bytes());
    h
}

/// Decode and validate a frame header. Every rejection names what was
/// wrong — a garbled stream must be diagnosable from the error alone.
pub fn decode_frame_header(h: &[u8]) -> Result<FrameHeader> {
    if h.len() < FRAME_HEADER_LEN {
        return Err(Error::Protocol(format!(
            "truncated frame header: {} of {FRAME_HEADER_LEN} bytes",
            h.len()
        )));
    }
    if h[0..4] != FRAME_MAGIC {
        return Err(Error::Protocol(format!(
            "bad frame magic {:02x?} (expected {:02x?})",
            &h[0..4],
            FRAME_MAGIC
        )));
    }
    let version = h[4];
    if version != WIRE_VERSION {
        return Err(Error::Protocol(format!(
            "frame version {version} not supported (this build speaks {WIRE_VERSION})"
        )));
    }
    let kind = FrameKind::from_byte(h[5])
        .ok_or_else(|| Error::Protocol(format!("unknown frame kind {}", h[5])))?;
    let dtype = h[6];
    if !matches!(dtype, 0 | 4 | 8) {
        return Err(Error::Protocol(format!("unknown frame dtype tag {dtype}")));
    }
    if h[7] != 0 {
        return Err(Error::Protocol(format!(
            "reserved frame byte is {} (must be 0)",
            h[7]
        )));
    }
    let src = u32::from_le_bytes(h[8..12].try_into().unwrap()) as usize;
    let tag = u64::from_le_bytes(h[12..20].try_into().unwrap());
    let seq = u64::from_le_bytes(h[20..28].try_into().unwrap());
    let len = u64::from_le_bytes(h[28..36].try_into().unwrap()) as usize;
    Ok(FrameHeader {
        kind,
        dtype,
        src,
        tag,
        seq,
        len,
    })
}

/// Read one frame from a byte stream. `Ok(None)` is a clean EOF at a
/// frame boundary (the peer closed); ending mid-frame is
/// [`Error::Protocol`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<(FrameHeader, Vec<u8>)>> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let mut got = 0;
    while got < FRAME_HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(Error::Protocol(format!(
                    "stream ended mid-header: {got} of {FRAME_HEADER_LEN} bytes"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let h = decode_frame_header(&header)?;
    let mut payload = vec![0u8; h.len];
    let mut got = 0;
    while got < h.len {
        match r.read(&mut payload[got..]) {
            Ok(0) => {
                return Err(Error::Protocol(format!(
                    "stream ended mid-payload: {got} of {} bytes",
                    h.len
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(Some((h, payload)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(kind: FrameKind, dtype: u8, src: usize, tag: u64, seq: u64, payload: &[u8]) -> Vec<u8> {
        let mut f = encode_frame_header(kind, dtype, src, tag, seq, payload.len()).to_vec();
        f.extend_from_slice(payload);
        f
    }

    #[test]
    fn header_roundtrip() {
        let h = encode_frame_header(FrameKind::Data, 8, 3, 12_345, 678, 4096);
        let back = decode_frame_header(&h).unwrap();
        assert_eq!(
            back,
            FrameHeader {
                kind: FrameKind::Data,
                dtype: 8,
                src: 3,
                tag: 12_345,
                seq: 678,
                len: 4096,
            }
        );
    }

    #[test]
    fn read_frame_roundtrip_and_clean_eof() {
        let payload = b"\x02\x00\x00\x00\x00\x00\x00\x00abcdefgh".to_vec();
        let mut stream =
            frame_bytes(FrameKind::Data, 4, 1, 7, 0, &payload);
        stream.extend(frame_bytes(FrameKind::Barrier, DTYPE_OPAQUE, 2, 9, 0, &[]));
        let mut r = &stream[..];
        let (h1, p1) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(h1.kind, FrameKind::Data);
        assert_eq!(h1.src, 1);
        assert_eq!(p1, payload);
        let (h2, p2) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(h2.kind, FrameKind::Barrier);
        assert_eq!(h2.tag, 9);
        assert!(p2.is_empty());
        // Clean EOF at the frame boundary.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_header_rejected() {
        let full = encode_frame_header(FrameKind::Data, 4, 0, 1, 2, 0);
        for cut in [1, 4, FRAME_HEADER_LEN - 1] {
            let mut r = &full[..cut];
            let err = read_frame(&mut r).unwrap_err();
            assert!(
                matches!(err, Error::Protocol(ref m) if m.contains("mid-header")),
                "cut at {cut}: {err}"
            );
        }
        // Slice-level decode reports truncation too.
        let err = decode_frame_header(&full[..10]).unwrap_err();
        assert!(matches!(err, Error::Protocol(ref m) if m.contains("truncated")));
    }

    #[test]
    fn truncated_payload_rejected() {
        let f = frame_bytes(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, b"0123456789");
        let mut r = &f[..f.len() - 3];
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(err, Error::Protocol(ref m) if m.contains("mid-payload")));
    }

    #[test]
    fn garbage_magic_rejected() {
        let mut f = frame_bytes(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, &[]);
        f[0] = b'X';
        let err = decode_frame_header(&f).unwrap_err();
        assert!(matches!(err, Error::Protocol(ref m) if m.contains("magic")), "{err}");
        let mut r = &f[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn future_version_rejected_precisely() {
        let mut f = encode_frame_header(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, 0);
        f[4] = WIRE_VERSION + 1;
        let err = decode_frame_header(&f).unwrap_err();
        match err {
            Error::Protocol(m) => {
                assert!(m.contains(&format!("version {}", WIRE_VERSION + 1)), "{m}");
                assert!(m.contains(&format!("speaks {WIRE_VERSION}")), "{m}");
            }
            other => panic!("expected Protocol error, got {other}"),
        }
    }

    #[test]
    fn unknown_kind_dtype_and_reserved_rejected() {
        let mut f = encode_frame_header(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, 0);
        f[5] = 9;
        assert!(matches!(decode_frame_header(&f), Err(Error::Protocol(_))));
        let mut f = encode_frame_header(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, 0);
        f[6] = 3;
        assert!(matches!(decode_frame_header(&f), Err(Error::Protocol(_))));
        let mut f = encode_frame_header(FrameKind::Data, DTYPE_OPAQUE, 0, 1, 0, 0);
        f[7] = 1;
        assert!(matches!(decode_frame_header(&f), Err(Error::Protocol(_))));
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("channel").unwrap(), TransportKind::Channel);
        assert_eq!(TransportKind::parse(" tcp ").unwrap(), TransportKind::Tcp);
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Unix);
        assert!(TransportKind::parse("mpi").is_err());
        for k in [TransportKind::Channel, TransportKind::Tcp, TransportKind::Unix] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn transport_guard_overrides_and_restores() {
        // The un-overridden default depends on PALLAS_TRANSPORT (the CI
        // socket leg sets it), so capture it rather than assume Channel.
        let ambient = default_transport();
        {
            let _g = TransportGuard::set(TransportKind::Unix);
            assert_eq!(default_transport(), TransportKind::Unix);
            {
                let _g2 = TransportGuard::set(TransportKind::Tcp);
                assert_eq!(default_transport(), TransportKind::Tcp);
            }
            assert_eq!(default_transport(), TransportKind::Unix);
        }
        assert_eq!(default_transport(), ambient);
    }
}
