//! The nonblocking request engine — everything that sits *above* the
//! [`Transport`](super::Transport) seam.
//!
//! This module owns [`Comm`] (the per-rank endpoint), the MPI-style
//! request machinery (`isend_*`/`irecv`/`wait*`/`test`, post order
//! matching arrivals per `(source, tag)` — the nonovertaking rule), the
//! ARQ layer (per-stream wire sequence numbers, the resequencer,
//! duplicate suppression, retry/retransmit clocks, limbo recovery), the
//! registered [`BufferPool`] with its receiver-returns-to-sender cycle,
//! fault injection at the delivery seam, plan capture, and the
//! [`Cluster`] launcher. None of it knows how bytes move: every backend
//! — the in-process channel mesh, TCP, Unix-domain sockets — plugs in
//! below [`Comm::post`]/[`Comm::pump`] and inherits all of it unchanged.
//! The architecture story and the backend contract live in the
//! [`crate::comm`] module docs.

use super::channel::ChannelTransport;
use super::faults::{self, FaultPlan, FaultStats, Verdict};
use super::plan;
use super::socket::SocketTransport;
use super::transport::{
    clone_body, default_transport, parse_wire, wire_bytes_of, wire_of, AnyArc, Arrival, Body,
    Message, Transport, TransportKind, TypedBody,
};
use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};
use crate::util::env::{parse_u64, EnvNum};
use std::any::TypeId;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::marker::PhantomData;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Default fatal receive deadline in milliseconds — generous, but converts
/// a deadlock (the classic distributed-programming failure mode) into an
/// error instead of a hang. Short under `cfg(test)` so a deadlocked unit
/// test fails in seconds. Overridable via the `PALLAS_RECV_TIMEOUT_MS`
/// environment variable (read once per [`Cluster::run`]); an explicit `0`
/// means **no deadline**, consistent with the crate-wide `0` = uncapped
/// convention for caps.
const DEFAULT_RECV_TIMEOUT_MS: u64 = if cfg!(test) { 5_000 } else { 60_000 };

/// Environment variable overriding the fatal receive deadline
/// (milliseconds; `0` = no deadline).
pub const RECV_TIMEOUT_ENV: &str = "PALLAS_RECV_TIMEOUT_MS";

/// Parse a `PALLAS_RECV_TIMEOUT_MS` value through the shared
/// [`crate::util::env`] parser: absence or garbage falls back to the
/// default, an explicit `0` disables the deadline (`None`).
fn parse_recv_timeout(raw: Option<&str>) -> Option<Duration> {
    match parse_u64(RECV_TIMEOUT_ENV, raw) {
        EnvNum::Value(0) => None,
        EnvNum::Value(ms) => Some(Duration::from_millis(ms)),
        EnvNum::Unset | EnvNum::Malformed => Some(Duration::from_millis(DEFAULT_RECV_TIMEOUT_MS)),
    }
}

/// The fatal receive deadline currently configured by the environment
/// (`None` = no deadline).
pub fn configured_recv_timeout() -> Option<Duration> {
    parse_recv_timeout(std::env::var(RECV_TIMEOUT_ENV).ok().as_deref())
}

/// Default retry/straggler threshold in milliseconds: how long a blocked
/// receive waits before it counts itself stalled, bumps the retry
/// counters, and asks the fault layer to retransmit anything withheld on
/// its stream. Backoff doubles per firing (capped at 2^6 x the base), so
/// an idle wait does not busy-poll.
const DEFAULT_RETRY_TIMEOUT_MS: u64 = if cfg!(test) { 250 } else { 2_000 };

/// Environment variable overriding the retry/straggler threshold
/// (milliseconds; `0` disables retries and the straggler watchdog).
pub const RETRY_TIMEOUT_ENV: &str = "PALLAS_RETRY_TIMEOUT_MS";

/// Parse a `PALLAS_RETRY_TIMEOUT_MS` value: absence or garbage falls back
/// to the default, an explicit `0` disables retries (`None`).
fn parse_retry_timeout(raw: Option<&str>) -> Option<Duration> {
    match parse_u64(RETRY_TIMEOUT_ENV, raw) {
        EnvNum::Value(0) => None,
        EnvNum::Value(ms) => Some(Duration::from_millis(ms)),
        EnvNum::Unset | EnvNum::Malformed => {
            Some(Duration::from_millis(DEFAULT_RETRY_TIMEOUT_MS))
        }
    }
}

/// The retry threshold currently configured by the environment.
fn configured_retry_timeout() -> Option<Duration> {
    parse_retry_timeout(std::env::var(RETRY_TIMEOUT_ENV).ok().as_deref())
}

/// Default bound on recovery (retransmission) attempts per blocked
/// receive. Retry firings past the bound still count stragglers; they
/// just stop asking for retransmissions.
const DEFAULT_MAX_RETRANSMITS: u32 = 8;

/// Environment variable overriding the retransmission bound.
pub const MAX_RETRANSMITS_ENV: &str = "PALLAS_MAX_RETRANSMITS";

/// Parse a `PALLAS_MAX_RETRANSMITS` value (absence/garbage = default).
fn parse_max_retransmits(raw: Option<&str>) -> u32 {
    match parse_u64(MAX_RETRANSMITS_ENV, raw) {
        EnvNum::Value(n) => n.min(u32::MAX as u64) as u32,
        EnvNum::Unset | EnvNum::Malformed => DEFAULT_MAX_RETRANSMITS,
    }
}

/// The retransmission bound currently configured by the environment.
fn configured_max_retransmits() -> u32 {
    parse_max_retransmits(std::env::var(MAX_RETRANSMITS_ENV).ok().as_deref())
}

/// Environment variable capping the bytes each endpoint's registered
/// buffer pool may park (mirrors the scratch arenas'
/// `PALLAS_SCRATCH_CAP_BYTES` policy: absent/garbage means the default,
/// an explicit `0` means uncapped). Read once per [`Cluster::run`].
pub const COMM_POOL_CAP_ENV: &str = "PALLAS_COMM_POOL_CAP_BYTES";

/// Default per-endpoint pool cap — far above any steady-state message
/// working set in this crate, but a hard bound on pathological growth.
pub const DEFAULT_COMM_POOL_CAP_BYTES: usize = 64 << 20;

/// Parse a `PALLAS_COMM_POOL_CAP_BYTES` value into the effective cap
/// (`None` = uncapped).
fn parse_comm_pool_cap(raw: Option<&str>) -> Option<usize> {
    match parse_u64(COMM_POOL_CAP_ENV, raw) {
        EnvNum::Value(0) => None,
        EnvNum::Value(b) => Some(b as usize),
        EnvNum::Unset | EnvNum::Malformed => Some(DEFAULT_COMM_POOL_CAP_BYTES),
    }
}

/// The per-endpoint pool cap currently configured by the environment.
fn configured_comm_pool_cap() -> Option<usize> {
    parse_comm_pool_cap(std::env::var(COMM_POOL_CAP_ENV).ok().as_deref())
}


// ---------------------------------------------------------------------
// Registered comm-buffer pool
// ---------------------------------------------------------------------

/// A buffer on its way home: the type-erased `Vec<T>` plus the metadata
/// the owning pool needs to park it without downcasting.
struct PoolEntry {
    elem: TypeId,
    cap_elems: usize,
    bytes: usize,
    buf: Box<dyn Any + Send>,
}

/// The sender-owned return slot that travels (by `Arc`) inside every
/// pooled payload. Receivers push the dead buffer here; the owner drains
/// it on its next acquire.
type ReturnBin = Arc<Mutex<Vec<PoolEntry>>>;

/// A registered message payload: a buffer drawn from some endpoint's
/// registered buffer pool together with the handle that returns it there.
///
/// The body is reference-counted through the engine (`Arc<PooledBody>`),
/// so fan-out sends share one registration; whichever holder drops the
/// **last** reference performs the return — receiver-side for
/// point-to-point messages, the final tree member for a broadcast.
pub struct PooledBody<T: Scalar> {
    data: Vec<T>,
    home: ReturnBin,
}

impl<T: Scalar> PooledBody<T> {
    /// The payload contents.
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    /// Payload length in elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

impl<T: Scalar> Drop for PooledBody<T> {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.data);
        if buf.capacity() == 0 {
            return;
        }
        let entry = PoolEntry {
            elem: TypeId::of::<T>(),
            cap_elems: buf.capacity(),
            bytes: buf.capacity() * std::mem::size_of::<T>(),
            buf: Box::new(buf),
        };
        // A poisoned bin means its owner panicked; leaking the buffer to
        // the allocator is the only sensible fallback.
        if let Ok(mut bin) = self.home.lock() {
            bin.push(entry);
        }
    }
}

/// Counters describing one endpoint's registered-buffer pool.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommPoolStats {
    /// `pool_take` calls served while the pool was enabled.
    pub acquires: usize,
    /// Acquires served from parked/returned buffers (no allocation).
    pub hits: usize,
    /// Acquires that had to mint a fresh buffer. After warm-up a
    /// steady-state train step should add **zero** here.
    pub misses: usize,
    /// Buffers that came home from receivers.
    pub returns: usize,
    /// Returns dropped by the byte cap (`PALLAS_COMM_POOL_CAP_BYTES`) —
    /// the deallocation executed for real.
    pub evictions: usize,
    /// Bytes currently parked in the pool.
    pub pooled_bytes: usize,
    /// Extra buffers minted eagerly by [`Comm::pool_reserve`] pre-warming
    /// (parked alongside the missing take's fresh buffer so a pipelined
    /// size class misses at most once).
    pub reserved: usize,
}

/// A per-endpoint pool of registered message buffers (see the module
/// docs). Owned by [`Comm`]; all access goes through the endpoint.
struct BufferPool {
    bin: ReturnBin,
    free: Vec<PoolEntry>,
    pooled_bytes: usize,
    cap_bytes: Option<usize>,
    enabled: bool,
    /// Pre-warm depth (see [`Comm::pool_reserve`]): on a size class's
    /// *second* miss — the signal that the class is genuinely pipelined,
    /// keeping more than one buffer in flight at once — mint the rest of
    /// its rotation depth eagerly, so the class misses at most twice
    /// instead of once per step for the first `reserve_depth` steps.
    /// Depth-1 classes (staged and returned within a step) miss once and
    /// never pre-warm, and a class pre-warms **at most once**: later
    /// misses (e.g. re-misses of an evicted class under cap pressure)
    /// mint on demand only — so cold extras are bounded by one pre-warm
    /// per class and cannot keep displacing hot returns under a finite
    /// byte cap.
    reserve_depth: usize,
    /// Per-size-class rotation depth overrides ([`Comm::pool_reserve_for`]).
    /// A class with an entry here pre-warms to *its* depth instead of the
    /// endpoint-wide `reserve_depth`, so e.g. the DP ring's chunk rotation
    /// and the pipeline's replica stash can coexist without one global
    /// depth over- or under-minting for the other.
    reserve_for: HashMap<(TypeId, usize), usize>,
    /// Per-class pre-warm state: `false` after the first miss (observed),
    /// `true` once the second-miss pre-warm has run.
    warmed: HashMap<(TypeId, usize), bool>,
    acquires: usize,
    hits: usize,
    misses: usize,
    returns: usize,
    evictions: usize,
    reserved: usize,
}

impl BufferPool {
    fn new(cap_bytes: Option<usize>) -> Self {
        BufferPool {
            bin: Arc::new(Mutex::new(Vec::new())),
            free: Vec::new(),
            pooled_bytes: 0,
            cap_bytes,
            enabled: true,
            reserve_depth: 1,
            reserve_for: HashMap::new(),
            warmed: HashMap::new(),
            acquires: 0,
            hits: 0,
            misses: 0,
            returns: 0,
            evictions: 0,
            reserved: 0,
        }
    }

    /// Park every buffer currently sitting in the return bin (applying
    /// the cap — an over-cap return is evicted, i.e. truly deallocated).
    fn drain_returns(&mut self) {
        let drained: Vec<PoolEntry> = match self.bin.lock() {
            Ok(mut bin) => std::mem::take(&mut *bin),
            Err(_) => Vec::new(),
        };
        for entry in drained {
            self.returns += 1;
            if let Some(cap) = self.cap_bytes {
                if self.pooled_bytes + entry.bytes > cap {
                    self.evictions += 1;
                    continue;
                }
            }
            self.pooled_bytes += entry.bytes;
            self.free.push(entry);
        }
    }

    /// Acquire a buffer of exactly `len` elements with unspecified
    /// contents (senders overwrite every element they ship). Best-fit
    /// over the parked buffers; a miss mints a fresh zeroed buffer.
    fn take<T: Scalar>(&mut self, len: usize) -> Vec<T> {
        self.drain_returns();
        self.acquires += 1;
        let elem = TypeId::of::<T>();
        let mut best: Option<(usize, usize)> = None;
        for (i, e) in self.free.iter().enumerate() {
            let tighter = match best {
                None => true,
                Some((_, c)) => e.cap_elems < c,
            };
            if e.elem == elem && e.cap_elems >= len && tighter {
                best = Some((i, e.cap_elems));
            }
        }
        match best {
            Some((i, _)) => {
                self.hits += 1;
                let entry = self.free.swap_remove(i);
                self.pooled_bytes -= entry.bytes;
                let mut buf = *entry
                    .buf
                    .downcast::<Vec<T>>()
                    .expect("pool entry matches its TypeId");
                buf.resize(len, T::ZERO);
                buf
            }
            None => {
                self.misses += 1;
                // A second miss of the same size class means the class is
                // pipelined (its first buffer is still in flight): mint
                // the rest of its rotation depth in the same stroke — the
                // two on-demand mints plus these extras — with the cap
                // checked *before* each mint, so a full or tiny cap costs
                // nothing. Depth-1 classes miss once and never pre-warm,
                // and each class pre-warms at most once: an evicted
                // class's later re-misses must not be misread as
                // pipelining and keep parking dead extras under the cap.
                let depth = self
                    .reserve_for
                    .get(&(elem, len))
                    .copied()
                    .unwrap_or(self.reserve_depth);
                if depth > 1 {
                    match self.warmed.entry((elem, len)) {
                        std::collections::hash_map::Entry::Vacant(slot) => {
                            slot.insert(false); // first miss: observe only
                        }
                        std::collections::hash_map::Entry::Occupied(mut slot)
                            if !*slot.get() =>
                        {
                            slot.insert(true); // second miss: pre-warm once
                            for _ in 2..depth {
                                let bytes = len * std::mem::size_of::<T>();
                                if let Some(cap) = self.cap_bytes {
                                    if self.pooled_bytes + bytes > cap {
                                        break;
                                    }
                                }
                                let extra = vec![T::ZERO; len];
                                self.reserved += 1;
                                self.pooled_bytes += bytes;
                                self.free.push(PoolEntry {
                                    elem,
                                    cap_elems: extra.capacity(),
                                    bytes,
                                    buf: Box::new(extra),
                                });
                            }
                        }
                        std::collections::hash_map::Entry::Occupied(_) => {}
                    }
                }
                vec![T::ZERO; len]
            }
        }
    }

    /// Wrap a buffer as a registered payload that returns here on drop.
    fn wrap<T: Scalar>(&self, data: Vec<T>) -> PooledBody<T> {
        PooledBody {
            data,
            home: self.bin.clone(),
        }
    }

    fn stats(&self) -> CommPoolStats {
        CommPoolStats {
            acquires: self.acquires,
            hits: self.hits,
            misses: self.misses,
            returns: self.returns,
            evictions: self.evictions,
            pooled_bytes: self.pooled_bytes,
            reserved: self.reserved,
        }
    }
}

/// A completed receive's payload: either an owned buffer (unpooled typed
/// path, wire fallback) or a registered buffer borrowed from the sender's
/// pool. Consume via [`Payload::as_slice`] and drop (the drop performs
/// the return), or take ownership with [`Payload::into_owned`].
pub enum Payload<T: Scalar> {
    /// The receiver owns the buffer outright.
    Owned(Vec<T>),
    /// A registered buffer; dropping the last reference returns it to the
    /// sender's pool.
    Pooled(Arc<PooledBody<T>>),
}

impl<T: Scalar> Payload<T> {
    /// The payload contents.
    pub fn as_slice(&self) -> &[T] {
        match self {
            Payload::Owned(v) => v.as_slice(),
            Payload::Pooled(p) => p.as_slice(),
        }
    }

    /// Payload length in elements.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// Take ownership of the contents. Owned payloads move; pooled
    /// payloads are copied out and the registered buffer returns home.
    pub fn into_owned(self) -> Vec<T> {
        match self {
            Payload::Owned(v) => v,
            Payload::Pooled(p) => p.as_slice().to_vec(),
        }
    }

    /// Wrap the payload as a tensor of `shape` **without copying**: an
    /// owned payload moves its buffer in, and a registered payload backs
    /// the tensor directly ([`Tensor::from_pooled`]) — reads stay
    /// zero-copy, mutation promotes copy-on-write, and dropping the
    /// tensor (or its last clone) returns the buffer to the sender's
    /// pool. This is how the primitives' receive sides hand message
    /// payloads to callers with zero post-completion copies.
    pub fn into_tensor(self, shape: &[usize]) -> Result<Tensor<T>> {
        match self {
            Payload::Owned(v) => Tensor::from_vec(shape, v),
            Payload::Pooled(p) => Tensor::from_pooled(shape, p),
        }
    }
}

/// Serializer stored in [`TypedBody`] for pooled payloads (the wire
/// fallback for [`Comm::recv_bytes`] and element-type mismatches).
fn pooled_wire_of<T: Scalar>(data: &AnyArc) -> Vec<u8> {
    let p = data
        .downcast_ref::<PooledBody<T>>()
        .expect("pooled body serializer sees its own element type");
    let v = p.as_slice();
    let mut buf = Vec::with_capacity(8 + v.len() * T::WIRE_SIZE);
    buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
    T::write_bytes(v, &mut buf);
    buf
}

/// Receiver-side fault state: the seeded plan plus whatever it is
/// currently withholding (see [`faults`] for the model).
struct FaultEngine {
    plan: FaultPlan,
    /// Messages held back by delay/reorder verdicts, with their release
    /// deadlines.
    delayed: Vec<(Instant, Message)>,
    /// Withheld payloads by stream and wire sequence: dropped messages
    /// (sequence at or past the stream's resequencer cursor) awaiting
    /// retransmission, and pristine copies of truncated messages
    /// (sequence behind the cursor) awaiting decode-failure recovery.
    limbo: HashMap<(usize, u64), BTreeMap<u64, Body>>,
}

impl FaultEngine {
    fn new(plan: FaultPlan) -> Self {
        FaultEngine {
            plan,
            delayed: Vec::new(),
            limbo: HashMap::new(),
        }
    }
}

/// Per-rank traffic counters (used by benches and the coordinator's metric
/// dump).
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Messages sent by this rank.
    pub messages_sent: usize,
    /// Payload bytes sent by this rank (wire-equivalent volume).
    pub bytes_sent: usize,
    /// Messages received.
    pub messages_received: usize,
    /// Payload bytes received (wire-equivalent volume).
    pub bytes_received: usize,
    /// Nonblocking receives posted (`irecv`).
    pub irecvs_posted: usize,
    /// Peak number of simultaneously outstanding receive requests.
    pub max_in_flight: usize,
    /// Messages delivered through the typed zero-copy path.
    pub zero_copy_msgs: usize,
    /// Messages that crossed the serialized wire format (sent or decoded).
    pub wire_msgs: usize,
    /// Wall-clock seconds this rank spent blocked completing receives.
    pub wait_time_s: f64,
    /// Registered buffer-pool counters (`comm_pool_*` on the MetricLog).
    pub pool: CommPoolStats,
    /// Fault-injection and recovery counters (`fault_*` on the
    /// MetricLog): injected faults, retries, retransmissions, suppressed
    /// duplicates, stragglers, swept abandons, longest stall.
    pub faults: FaultStats,
}

/// Handle for a posted nonblocking send.
///
/// Channel sends in this substrate are eager and buffered, so the send is
/// already in flight when the handle is returned; [`Comm::wait_send`]
/// completes it. The handle still exists so call sites read like MPI and
/// so a future bounded-channel backend can block in `wait_send`.
#[must_use = "complete the posted send with Comm::wait_send"]
#[derive(Debug)]
pub struct SendRequest {
    dst: usize,
    tag: u64,
}

impl SendRequest {
    /// Destination rank of the posted send.
    pub fn destination(&self) -> usize {
        self.dst
    }

    /// Message tag of the posted send.
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

/// Handle for a posted nonblocking receive of `T` elements.
///
/// Complete with [`Comm::wait`] / [`Comm::wait_all`]; probe with
/// [`Comm::test`]. Requests on the same `(source, tag)` match arrivals in
/// post order regardless of completion order. A dropped request leaks its
/// matched message (it is never mis-delivered to a later request).
#[must_use = "complete the posted receive with Comm::wait"]
#[derive(Debug)]
pub struct RecvRequest<T: Scalar> {
    src: usize,
    tag: u64,
    seq: u64,
    _elem: PhantomData<fn() -> T>,
}

impl<T: Scalar> RecvRequest<T> {
    /// Source rank this receive matches.
    pub fn source(&self) -> usize {
        self.src
    }

    /// Message tag this receive matches.
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

/// One rank's endpoint into the cluster.
pub struct Comm {
    rank: usize,
    size: usize,
    /// The byte-moving backend (see [`crate::comm::Transport`]). Every
    /// guarantee the engine relies on — FIFO per `(src, dst)` pair,
    /// staging ownership, delivery-seam transparency — is part of the
    /// trait contract, so the engine never inspects which backend it is
    /// running over.
    transport: Box<dyn Transport>,
    /// Messages that arrived before being matched to a posted receive.
    parked: HashMap<(usize, u64), VecDeque<Body>>,
    /// Arrivals already matched to a posted sequence number.
    ready: HashMap<(usize, u64, u64), Body>,
    /// Next request sequence number per `(source, tag)`.
    next_posted: HashMap<(usize, u64), u64>,
    /// Next arrival sequence number per `(source, tag)`.
    next_arrived: HashMap<(usize, u64), u64>,
    /// Next outbound wire sequence number per `(destination, tag)`.
    next_send: HashMap<(usize, u64), u64>,
    /// Receiver resequencer cursor: next expected wire sequence per
    /// `(source, tag)` stream. Arrivals behind the cursor are duplicates
    /// (suppressed); arrivals past it wait in `ooo` until the gap fills.
    next_wire: HashMap<(usize, u64), u64>,
    /// Out-of-order arrivals held until their wire-sequence gap fills.
    ooo: HashMap<(usize, u64), BTreeMap<u64, Body>>,
    /// Arrival sequence numbers owed to abandoned requests: the matching
    /// message is discarded at promotion (dropping the payload returns a
    /// registered buffer to its sender's pool).
    discard: HashSet<(usize, u64, u64)>,
    /// Outstanding receive requests right now.
    in_flight: usize,
    /// Force every payload through the serialized wire format (bench knob).
    wire_format: bool,
    /// Registered message-buffer pool (see the module docs).
    pool: BufferPool,
    /// Fatal per-receive deadline (`None` = wait forever).
    recv_timeout: Option<Duration>,
    /// Retry/straggler threshold (`None` = no retries, no watchdog).
    retry_timeout: Option<Duration>,
    /// Bound on retransmission-recovery attempts per blocked receive.
    max_retransmits: u32,
    /// Installed fault plan and its withheld messages, if any.
    faults: Option<FaultEngine>,
    /// Plan-capture recorder, when this endpoint is in capture mode
    /// (see [`plan`] and [`crate::analysis`]). `None` in production.
    plan: Option<Arc<Mutex<plan::PlanRecorder>>>,
    stats: CommStats,
}

impl Comm {
    /// This endpoint's world rank.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Traffic counters so far. Drains the buffer pool's return bin first
    /// so in-transit returns are reflected in the `pool` counters.
    pub fn stats(&mut self) -> CommStats {
        self.pool.drain_returns();
        let mut s = self.stats;
        s.pool = self.pool.stats();
        s
    }

    /// Receive requests currently outstanding.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Force (`true`) or lift (`false`) the serialized wire format for
    /// every subsequent send. The default is the typed zero-copy path;
    /// benches flip this to measure the blocking/serializing baseline.
    pub fn set_wire_format(&mut self, on: bool) {
        self.wire_format = on;
    }

    /// Whether the serialized wire format is currently forced.
    pub fn wire_format(&self) -> bool {
        self.wire_format
    }

    // ------------------------------------------------------------------
    // Failure-model knobs (see the module docs)
    // ------------------------------------------------------------------

    /// Override the fatal per-receive deadline (`None` = wait forever).
    /// The initial value comes from `PALLAS_RECV_TIMEOUT_MS` at cluster
    /// launch; tests use this setter because endpoints are per-thread
    /// while the environment is process-global.
    pub fn set_recv_timeout(&mut self, deadline: Option<Duration>) {
        self.recv_timeout = deadline;
    }

    /// The fatal per-receive deadline currently in force.
    pub fn recv_timeout(&self) -> Option<Duration> {
        self.recv_timeout
    }

    /// Override the retry/straggler threshold (`None` disables retries
    /// and the progress watchdog). Initial value:
    /// `PALLAS_RETRY_TIMEOUT_MS`.
    pub fn set_retry_timeout(&mut self, threshold: Option<Duration>) {
        self.retry_timeout = threshold;
    }

    /// Override the bound on retransmission-recovery attempts per
    /// blocked receive. Initial value: `PALLAS_MAX_RETRANSMITS`.
    pub fn set_max_retransmits(&mut self, bound: u32) {
        self.max_retransmits = bound;
    }

    /// Install (or clear) a fault plan on this endpoint. Anything a
    /// previous plan still withholds is released first so no payload is
    /// stranded by reconfiguration. A plan carrying `retry_ms=` /
    /// `timeout_ms=` overrides applies them to this endpoint's retry
    /// threshold and fatal deadline.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        if let Some(eng) = self.faults.take() {
            let FaultEngine { delayed, limbo, .. } = eng;
            let mut held: Vec<Message> = delayed.into_iter().map(|(_, m)| m).collect();
            for ((src, tag), q) in limbo {
                let cursor = *self.next_wire.get(&(src, tag)).unwrap_or(&0);
                for (seq, body) in q {
                    // Stale pristine copies of already-delivered
                    // truncated messages just drop (the buffer returns
                    // home); undelivered payloads are released.
                    if seq >= cursor {
                        held.push(Message {
                            src,
                            tag,
                            seq,
                            body,
                        });
                    }
                }
            }
            held.sort_by_key(|m| (m.src, m.tag, m.seq));
            for m in held {
                self.resequence(m);
            }
        }
        self.faults = plan.map(FaultEngine::new);
        if let Some(eng) = self.faults.as_ref() {
            if let Some(ms) = eng.plan.retry_ms {
                self.retry_timeout = (ms > 0).then(|| Duration::from_millis(ms));
            }
            if let Some(ms) = eng.plan.timeout_ms {
                self.recv_timeout = (ms > 0).then(|| Duration::from_millis(ms));
            }
        }
    }

    /// The kill-switch half of the fault plan: the coordinator calls this
    /// at the top of every training step, and a `kill:rank=R,step=K`
    /// clause matching this rank and `step` turns into an error — the
    /// deterministic stand-in for a rank dying mid-run.
    pub fn fault_step(&mut self, step: u64) -> Result<()> {
        if let Some(eng) = self.faults.as_ref() {
            if eng.plan.kills_at(self.rank, step) {
                return Err(Error::Comm(format!(
                    "rank {} killed by fault plan at step {step}",
                    self.rank
                )));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Registered buffer pool
    // ------------------------------------------------------------------

    /// Whether the registered buffer pool is enabled (the default).
    pub fn pool_on(&self) -> bool {
        self.pool.enabled
    }

    /// Enable (default) or disable the registered buffer pool. Disabled,
    /// the pooled send helpers degrade to the move-semantics unpooled
    /// paths — the benches' baseline. Results are bitwise identical
    /// either way; only the allocator traffic differs.
    pub fn set_comm_pool(&mut self, on: bool) {
        self.pool.enabled = on;
    }

    /// Override this endpoint's pool byte cap (`None` = uncapped) — a
    /// testing/tuning knob; the initial cap comes from
    /// `PALLAS_COMM_POOL_CAP_BYTES` at cluster launch.
    pub fn set_pool_cap_bytes(&mut self, cap: Option<usize>) {
        self.pool.cap_bytes = cap;
    }

    /// Pipeline-depth-aware pool pre-warming: when a size class misses a
    /// **second** time — proof that the class keeps more than one buffer
    /// in flight at once — mint its full rotation of `depth` buffers in
    /// that stroke (the two on-demand mints plus `depth - 2` parked
    /// extras, byte cap checked before each mint).
    ///
    /// A pipelined step keeps several buffers of one class alive at once
    /// — broadcast replicas stashed until backward, the micro-batch
    /// prefetch overlap — so without pre-warming the first `depth` steps
    /// each record one spurious miss per class while the rotation depth
    /// is minted. With it, a pipelined class misses at most twice and a
    /// depth-1 class (staged and returned within its step) exactly once —
    /// and because depth-1 classes never mint extras and each class
    /// pre-warms at most once, cold pre-warm cannot displace hot returns
    /// under a finite cap. Extra mints are counted under
    /// [`CommPoolStats::reserved`], not as further misses. `depth <= 1`
    /// restores the mint-on-demand default.
    pub fn pool_reserve(&mut self, depth: usize) {
        self.pool.reserve_depth = depth.max(1);
    }

    /// Per-size-class override of [`Comm::pool_reserve`]: the class of
    /// `len`-element `T` buffers pre-warms to `depth` instead of the
    /// endpoint-wide depth. The ring collectives use this for their chunk
    /// rotation (one chunk in flight to the neighbour while the next is
    /// being staged needs depth 2) without inflating every other class,
    /// and without the pipeline's global depth under-minting the ring.
    /// `depth <= 1` removes the override.
    pub fn pool_reserve_for<T: Scalar>(&mut self, len: usize, depth: usize) {
        let key = (TypeId::of::<T>(), len);
        if depth <= 1 {
            self.pool.reserve_for.remove(&key);
        } else {
            self.pool.reserve_for.insert(key, depth);
        }
    }

    /// This endpoint's pool counters (return bin drained first).
    pub fn pool_stats(&mut self) -> CommPoolStats {
        self.pool.drain_returns();
        self.pool.stats()
    }

    /// Acquire a registered staging buffer of exactly `len` elements with
    /// **unspecified contents** (fill it before sending). Served from the
    /// pool's parked/returned buffers when possible; with the pool
    /// disabled this is a plain allocation, uncounted.
    pub fn pool_take<T: Scalar>(&mut self, len: usize) -> Vec<T> {
        if self.pool.enabled {
            self.pool.take(len)
        } else {
            vec![T::ZERO; len]
        }
    }

    /// Copy `data` into a registered buffer and wrap it as a shareable
    /// pooled payload (broadcast trees fan the `Arc` out). Pool must be
    /// enabled — callers branch on [`Comm::pool_on`].
    pub fn pool_stage<T: Scalar>(&mut self, data: &[T]) -> Arc<PooledBody<T>> {
        let mut stage = self.pool.take(data.len());
        stage.copy_from_slice(data);
        Arc::new(self.pool.wrap(stage))
    }

    /// Adopt an already-filled buffer (typically one obtained from
    /// [`Comm::pool_take`]) as a registered payload **without copying**:
    /// the buffer returns to this endpoint's pool when the payload drops.
    /// This is how an accumulator assembled in a pool buffer — the
    /// sum-reduce root's fused add-out-of-payload result, a DP bucket —
    /// becomes a pool-backed [`Tensor`](crate::tensor::Tensor) or an
    /// onward zero-copy send.
    pub fn pool_wrap<T: Scalar>(&mut self, data: Vec<T>) -> Arc<PooledBody<T>> {
        Arc::new(self.pool.wrap(data))
    }

    // ------------------------------------------------------------------
    // Plan capture (see the `plan` module and `crate::analysis`)
    // ------------------------------------------------------------------

    /// Switch this endpoint into plan-capture mode: every subsequent send
    /// post, receive post, completion, timeout, and barrier is recorded
    /// as a [`plan::PlanEvent`] until [`Comm::plan_take`] drains the log.
    pub fn plan_begin(&mut self) {
        self.plan = Some(Arc::new(Mutex::new(plan::PlanRecorder::new())));
    }

    /// Leave capture mode and return the recorded events (`None` if no
    /// capture was active).
    pub fn plan_take(&mut self) -> Option<Vec<plan::ScopedEvent>> {
        self.plan.take().map(|h| match h.lock() {
            Ok(mut g) => g.take_events(),
            Err(_) => Vec::new(),
        })
    }

    /// Whether a plan capture is active.
    pub fn plan_active(&self) -> bool {
        self.plan.is_some()
    }

    /// Shared handle to the active recorder, if any — what
    /// [`plan::PlanScope`] guards clone so they outlive the `&mut Comm`
    /// borrow that created them.
    pub fn plan_handle(&self) -> Option<Arc<Mutex<plan::PlanRecorder>>> {
        self.plan.clone()
    }

    /// Declare the capture phase subsequent events belong to (no-op when
    /// not capturing).
    pub fn plan_phase(&self, phase: plan::Phase) {
        if let Some(h) = &self.plan {
            if let Ok(mut g) = h.lock() {
                g.set_phase(phase);
            }
        }
    }

    /// Record one event on the active recorder. Callers guard with
    /// `self.plan.is_some()` so the production path is one branch.
    fn plan_record(&self, event: plan::PlanEvent) {
        if let Some(h) = &self.plan {
            if let Ok(mut g) = h.lock() {
                g.record(event);
            }
        }
    }

    // ------------------------------------------------------------------
    // Posting sends
    // ------------------------------------------------------------------

    fn post(
        &mut self,
        dst: usize,
        tag: u64,
        body: Body,
        dtype: &'static str,
        pooled: bool,
    ) -> Result<()> {
        if dst >= self.size {
            return Err(Error::Comm(format!(
                "send to rank {dst} out of range (world {})",
                self.size
            )));
        }
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += body.wire_len();
        if matches!(body, Body::Bytes(_)) {
            self.stats.wire_msgs += 1;
        }
        let slot = self.next_send.entry((dst, tag)).or_insert(0);
        let seq = *slot;
        *slot += 1;
        if self.plan.is_some() {
            self.plan_record(plan::PlanEvent::Send {
                dst,
                tag,
                seq,
                bytes: body.wire_len(),
                dtype,
                pooled,
            });
        }
        self.transport.send(
            dst,
            Message {
                src: self.rank,
                tag,
                seq,
                body,
            },
        )
    }

    fn typed_body<T: Scalar>(data: Vec<T>) -> Body {
        Body::Typed(TypedBody {
            len: data.len(),
            wire_size: T::WIRE_SIZE,
            data: Arc::new(data),
            to_wire: wire_of::<T>,
        })
    }

    fn shared_body<T: Scalar>(data: &Arc<Vec<T>>) -> Body {
        Body::Typed(TypedBody {
            len: data.len(),
            wire_size: T::WIRE_SIZE,
            data: data.clone() as AnyArc,
            to_wire: wire_of::<T>,
        })
    }

    /// Send raw wire-format bytes to `dst` with `tag`. Never blocks
    /// (channels are unbounded; backpressure is not modelled — the paper's
    /// experiments are synchronous SPMD).
    pub fn send_bytes(&mut self, dst: usize, tag: u64, payload: Vec<u8>) -> Result<()> {
        self.post(dst, tag, Body::Bytes(payload), "bytes", false)
    }

    /// Post a nonblocking send of a typed slice (one buffer copy, no
    /// per-element serialization; wire format if forced).
    pub fn isend_slice<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: &[T],
    ) -> Result<SendRequest> {
        if self.wire_format {
            let mut buf = Vec::with_capacity(8 + data.len() * T::WIRE_SIZE);
            buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
            T::write_bytes(data, &mut buf);
            self.post(dst, tag, Body::Bytes(buf), std::any::type_name::<T>(), false)?;
        } else {
            self.post(
                dst,
                tag,
                Self::typed_body(data.to_vec()),
                std::any::type_name::<T>(),
                false,
            )?;
        }
        Ok(SendRequest { dst, tag })
    }

    /// Post a nonblocking send that *moves* the buffer — the zero-copy
    /// path for move-semantics primitives (scatter, all-to-all, adjoint
    /// sends whose local realization is deallocated).
    pub fn isend_vec<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: Vec<T>,
    ) -> Result<SendRequest> {
        if self.wire_format {
            return self.isend_slice(dst, tag, &data);
        }
        self.post(
            dst,
            tag,
            Self::typed_body(data),
            std::any::type_name::<T>(),
            false,
        )?;
        Ok(SendRequest { dst, tag })
    }

    /// Post a nonblocking send of a shared buffer — fan-out sends (e.g.
    /// the broadcast tree) clone only the `Arc`, never the data.
    pub fn isend_shared<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: &Arc<Vec<T>>,
    ) -> Result<SendRequest> {
        if self.wire_format {
            return self.isend_slice(dst, tag, data.as_slice());
        }
        self.post(
            dst,
            tag,
            Self::shared_body(data),
            std::any::type_name::<T>(),
            false,
        )?;
        Ok(SendRequest { dst, tag })
    }

    /// Post a nonblocking send of a **registered** buffer previously
    /// acquired with [`Comm::pool_take`]: the payload carries a handle to
    /// this endpoint's pool, and the receiver's completion returns the
    /// buffer here. With the pool disabled this degrades to the
    /// move-semantics [`Comm::isend_vec`]; with the wire format forced the
    /// buffer is serialized and returns home immediately.
    pub fn isend_pooled<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: Vec<T>,
    ) -> Result<SendRequest> {
        if !self.pool.enabled {
            return self.isend_vec(dst, tag, data);
        }
        if self.wire_format {
            let req = self.isend_slice(dst, tag, &data)?;
            drop(self.pool.wrap(data)); // straight back to the pool
            return Ok(req);
        }
        let body: Arc<PooledBody<T>> = Arc::new(self.pool.wrap(data));
        self.post(
            dst,
            tag,
            Body::Typed(TypedBody {
                len: body.len(),
                wire_size: T::WIRE_SIZE,
                data: body as AnyArc,
                to_wire: pooled_wire_of::<T>,
            }),
            std::any::type_name::<T>(),
            true,
        )?;
        Ok(SendRequest { dst, tag })
    }

    /// Stage `data` in a registered buffer from this endpoint's pool and
    /// post its send — the one-call form of the
    /// `pool_take`/`copy_from_slice`/[`Comm::isend_pooled`] sequence every
    /// pooled primitive send uses, so the staging contract lives in one
    /// place. With the pool disabled this degrades to the copying
    /// [`Comm::isend_slice`]; move-semantics call sites that want their
    /// unpooled fallback to *move* instead branch on [`Comm::pool_on`]
    /// and call [`Comm::isend_vec`] themselves.
    pub fn isend_staged<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: &[T],
    ) -> Result<SendRequest> {
        if !self.pool.enabled {
            return self.isend_slice(dst, tag, data);
        }
        let mut stage = self.pool.take(data.len());
        stage.copy_from_slice(data);
        self.isend_pooled(dst, tag, stage)
    }

    /// Post a nonblocking send of a shared pooled payload (from
    /// [`Comm::pool_stage`] or a received [`Payload::Pooled`] being
    /// forwarded) — fan-out clones only the `Arc`; the last holder's drop
    /// returns the buffer to the pool that staged it.
    pub fn isend_pooled_body<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        body: &Arc<PooledBody<T>>,
    ) -> Result<SendRequest> {
        if self.wire_format {
            return self.isend_slice(dst, tag, body.as_slice());
        }
        self.post(
            dst,
            tag,
            Body::Typed(TypedBody {
                len: body.len(),
                wire_size: T::WIRE_SIZE,
                data: body.clone() as AnyArc,
                to_wire: pooled_wire_of::<T>,
            }),
            std::any::type_name::<T>(),
            true,
        )?;
        Ok(SendRequest { dst, tag })
    }

    /// Complete a posted send. Eager channel sends are already in flight,
    /// so this returns immediately.
    pub fn wait_send(&mut self, _req: SendRequest) -> Result<()> {
        Ok(())
    }

    /// Blocking typed send: post + complete.
    pub fn send_slice<T: Scalar>(&mut self, dst: usize, tag: u64, data: &[T]) -> Result<()> {
        let req = self.isend_slice(dst, tag, data)?;
        self.wait_send(req)
    }

    /// Blocking typed send that moves its buffer (zero-copy).
    pub fn send_vec<T: Scalar>(&mut self, dst: usize, tag: u64, data: Vec<T>) -> Result<()> {
        let req = self.isend_vec(dst, tag, data)?;
        self.wait_send(req)
    }

    /// Blocking typed send of a shared buffer (fan-out without copies).
    pub fn send_shared<T: Scalar>(
        &mut self,
        dst: usize,
        tag: u64,
        data: &Arc<Vec<T>>,
    ) -> Result<()> {
        let req = self.isend_shared(dst, tag, data)?;
        self.wait_send(req)
    }

    // ------------------------------------------------------------------
    // Posting and completing receives
    // ------------------------------------------------------------------

    /// Post a nonblocking receive matching `(src, tag)`.
    pub fn irecv<T: Scalar>(&mut self, src: usize, tag: u64) -> Result<RecvRequest<T>> {
        self.irecv_as(src, tag, std::any::type_name::<T>())
    }

    /// [`Comm::irecv`] with an explicit dtype label for plan capture —
    /// `recv_bytes` posts through here so its wire-format receive is not
    /// misattributed to the placeholder element type.
    fn irecv_as<T: Scalar>(
        &mut self,
        src: usize,
        tag: u64,
        dtype: &'static str,
    ) -> Result<RecvRequest<T>> {
        if src >= self.size {
            return Err(Error::Comm(format!(
                "receive from rank {src} out of range (world {})",
                self.size
            )));
        }
        let slot = self.next_posted.entry((src, tag)).or_insert(0);
        let seq = *slot;
        *slot += 1;
        self.in_flight += 1;
        self.stats.irecvs_posted += 1;
        self.stats.max_in_flight = self.stats.max_in_flight.max(self.in_flight);
        if self.plan.is_some() {
            self.plan_record(plan::PlanEvent::RecvPost {
                src,
                tag,
                seq,
                dtype,
            });
        }
        Ok(RecvRequest {
            src,
            tag,
            seq,
            _elem: PhantomData,
        })
    }

    /// Assign the next unmatched arrival for `(src, tag)` its sequence
    /// number, moving it from the parked mailbox into the ready store —
    /// unless that sequence number is owed to an abandoned request, in
    /// which case the message is discarded (the payload drop returns any
    /// registered buffer to its sender) and the next one is tried.
    /// Returns whether an arrival was promoted into `ready`.
    fn promote_parked(&mut self, src: usize, tag: u64) -> bool {
        loop {
            let body = match self.parked.get_mut(&(src, tag)).and_then(|q| q.pop_front()) {
                Some(body) => body,
                None => return false,
            };
            let slot = self.next_arrived.entry((src, tag)).or_insert(0);
            let seq = *slot;
            *slot += 1;
            if self.discard.remove(&(src, tag, seq)) {
                self.stats.faults.abandoned_swept += 1;
                continue;
            }
            self.ready.insert((src, tag, seq), body);
            return true;
        }
    }

    /// Park a resequenced body at the tail of its stream's mailbox.
    fn park_in_order(&mut self, src: usize, tag: u64, body: Body) {
        self.parked.entry((src, tag)).or_default().push_back(body);
    }

    /// Feed one transport arrival through the wire-sequence layer:
    /// duplicates (sequence behind the stream cursor) are suppressed,
    /// early arrivals wait in the out-of-order buffer, and the in-order
    /// prefix — the arrival plus whatever it unblocks — parks in FIFO
    /// order. After this, parked order per stream equals wire-sequence
    /// order, so arrival sequence numbers equal wire sequence numbers.
    fn resequence(&mut self, msg: Message) {
        let key = (msg.src, msg.tag);
        let expected = *self.next_wire.get(&key).unwrap_or(&0);
        if msg.seq < expected {
            self.stats.faults.dups_suppressed += 1;
            return;
        }
        if msg.seq > expected {
            let held = self.ooo.entry(key).or_default().insert(msg.seq, msg.body);
            if held.is_some() {
                self.stats.faults.dups_suppressed += 1;
            }
            return;
        }
        let mut next = expected;
        let mut body = Some(msg.body);
        loop {
            let b = match body.take() {
                Some(b) => b,
                None => match self.ooo.get_mut(&key).and_then(|q| q.remove(&next)) {
                    Some(b) => b,
                    None => break,
                },
            };
            self.park_in_order(key.0, key.1, b);
            next += 1;
        }
        self.next_wire.insert(key, next);
    }

    /// Judge one transport arrival against the installed fault plan and
    /// act on the verdict; without a plan this is a straight resequence.
    fn deliver(&mut self, msg: Message) {
        let verdict = match self.faults.as_ref() {
            Some(eng) => eng.plan.decide(self.rank, msg.src, msg.tag, msg.seq),
            None => Verdict::Deliver,
        };
        match verdict {
            Verdict::Deliver => self.resequence(msg),
            Verdict::Delay(ms) | Verdict::Reorder(ms) => {
                if matches!(verdict, Verdict::Delay(_)) {
                    self.stats.faults.injected_delays += 1;
                } else {
                    self.stats.faults.injected_reorders += 1;
                }
                let until = Instant::now() + Duration::from_millis(ms);
                self.faults
                    .as_mut()
                    .expect("verdict implies an installed plan")
                    .delayed
                    .push((until, msg));
            }
            Verdict::Drop => {
                self.stats.faults.injected_drops += 1;
                self.faults
                    .as_mut()
                    .expect("verdict implies an installed plan")
                    .limbo
                    .entry((msg.src, msg.tag))
                    .or_default()
                    .insert(msg.seq, msg.body);
            }
            Verdict::Duplicate => {
                self.stats.faults.injected_dups += 1;
                let dup = Message {
                    src: msg.src,
                    tag: msg.tag,
                    seq: msg.seq,
                    body: clone_body(&msg.body),
                };
                self.resequence(msg);
                self.resequence(dup);
            }
            Verdict::Truncate => {
                self.stats.faults.injected_truncations += 1;
                let wire = wire_bytes_of(&msg.body);
                let corrupted = Body::Bytes(wire[..wire.len().saturating_sub(1)].to_vec());
                let Message { src, tag, seq, body } = msg;
                self.faults
                    .as_mut()
                    .expect("verdict implies an installed plan")
                    .limbo
                    .entry((src, tag))
                    .or_default()
                    .insert(seq, body);
                self.resequence(Message {
                    src,
                    tag,
                    seq,
                    body: corrupted,
                });
            }
        }
    }

    /// Drain the transport without blocking and release any held-back
    /// messages whose deadlines have passed.
    fn pump(&mut self) {
        while let Some(msg) = self.transport.try_recv() {
            self.deliver(msg);
        }
        self.release_due_faults();
    }

    /// Earliest release deadline among held-back messages, if any — a
    /// blocked receive must wake for it.
    fn next_fault_release(&self) -> Option<Instant> {
        self.faults
            .as_ref()
            .and_then(|eng| eng.delayed.iter().map(|(t, _)| *t).min())
    }

    /// Release every held-back message whose deadline has passed.
    fn release_due_faults(&mut self) {
        let mut due: Vec<Message> = match self.faults.as_mut() {
            Some(eng) if !eng.delayed.is_empty() => {
                let now = Instant::now();
                let mut out = Vec::new();
                let mut i = 0;
                while i < eng.delayed.len() {
                    if eng.delayed[i].0 <= now {
                        out.push(eng.delayed.swap_remove(i).1);
                    } else {
                        i += 1;
                    }
                }
                out
            }
            _ => return,
        };
        if due.is_empty() {
            return;
        }
        due.sort_by_key(|m| (m.src, m.tag, m.seq));
        for m in due {
            self.resequence(m);
        }
    }

    /// Simulated retransmission: release the stream's oldest withheld
    /// *undelivered* payload (sequence at or past the resequencer cursor
    /// — pristine copies of already-delivered truncated messages stay
    /// reserved for decode recovery). Returns whether anything was
    /// recovered.
    fn recover_from_limbo(&mut self, src: usize, tag: u64) -> bool {
        let cursor = *self.next_wire.get(&(src, tag)).unwrap_or(&0);
        let (seq, body) = {
            let Some(eng) = self.faults.as_mut() else {
                return false;
            };
            let Some(q) = eng.limbo.get_mut(&(src, tag)) else {
                return false;
            };
            let Some((&seq, _)) = q.range(cursor..).next() else {
                return false;
            };
            let body = q.remove(&seq).expect("key just observed");
            if q.is_empty() {
                eng.limbo.remove(&(src, tag));
            }
            (seq, body)
        };
        self.resequence(Message {
            src,
            tag,
            seq,
            body,
        });
        true
    }

    /// Take the pristine copy of a truncated message by exact wire
    /// sequence — the decode-failure recovery path.
    fn limbo_take(&mut self, src: usize, tag: u64, seq: u64) -> Option<Body> {
        let eng = self.faults.as_mut()?;
        let q = eng.limbo.get_mut(&(src, tag))?;
        let body = q.remove(&seq)?;
        if q.is_empty() {
            eng.limbo.remove(&(src, tag));
        }
        Some(body)
    }

    /// Release everything the fault layer withholds on one stream:
    /// held-back messages immediately (deadlines void), undelivered limbo
    /// payloads resequenced, stale truncation pristines dropped (their
    /// buffers return home). Called when a request on the stream is
    /// abandoned, so a withheld message cannot pin a registered buffer
    /// behind a dead request.
    fn flush_stream_faults(&mut self, src: usize, tag: u64) {
        let cursor = *self.next_wire.get(&(src, tag)).unwrap_or(&0);
        let Some(eng) = self.faults.as_mut() else {
            return;
        };
        let mut released: Vec<Message> = Vec::new();
        let mut i = 0;
        while i < eng.delayed.len() {
            if eng.delayed[i].1.src == src && eng.delayed[i].1.tag == tag {
                released.push(eng.delayed.swap_remove(i).1);
            } else {
                i += 1;
            }
        }
        if let Some(q) = eng.limbo.remove(&(src, tag)) {
            for (seq, body) in q {
                if seq >= cursor {
                    released.push(Message {
                        src,
                        tag,
                        seq,
                        body,
                    });
                }
            }
        }
        released.sort_by_key(|m| m.seq);
        for m in released {
            self.resequence(m);
        }
    }

    /// Retire an abandoned request's claim on its stream. If its message
    /// already arrived it is dropped now; otherwise its arrival sequence
    /// number is recorded as a debt and the message is discarded the
    /// moment it arrives — either way a registered payload returns to its
    /// sender's pool, and a *retried* request on the same stream (a fresh
    /// `irecv`) matches the retransmitted payload, never the stale one.
    fn abandon(&mut self, src: usize, tag: u64, seq: u64) {
        self.pump();
        if self.ready.remove(&(src, tag, seq)).is_some() {
            self.stats.faults.abandoned_swept += 1;
            return;
        }
        self.discard.insert((src, tag, seq));
        self.flush_stream_faults(src, tag);
        while self.promote_parked(src, tag) {}
    }

    /// Remove `(src, tag, seq)` from the ready store, promoting parked
    /// arrivals as needed. Does not touch the transport.
    fn take_ready(&mut self, src: usize, tag: u64, seq: u64) -> Option<Body> {
        loop {
            if let Some(body) = self.ready.remove(&(src, tag, seq)) {
                return Some(body);
            }
            if !self.promote_parked(src, tag) {
                return None;
            }
        }
    }

    /// Block until the arrival matched to `(src, tag, seq)` is available.
    ///
    /// The wait runs two clocks (see the module docs' failure model): the
    /// retry threshold fires repeatedly with exponential backoff —
    /// counting stragglers and asking the fault layer to retransmit
    /// anything withheld on this stream — and the fatal deadline abandons
    /// the request and errors. `None` deadlines wait forever.
    fn claim(&mut self, src: usize, tag: u64, seq: u64) -> Result<Body> {
        if let Some(body) = self.take_ready(src, tag, seq) {
            return Ok(body);
        }
        let start = Instant::now();
        let fatal = self.recv_timeout.map(|d| start + d);
        let mut attempt: u32 = 0;
        let mut next_retry = self.retry_timeout.map(|d| start + d);
        loop {
            self.pump();
            if let Some(body) = self.take_ready(src, tag, seq) {
                let stall = start.elapsed().as_secs_f64();
                if stall > self.stats.faults.max_stall_s {
                    self.stats.faults.max_stall_s = stall;
                }
                return Ok(body);
            }
            let now = Instant::now();
            if let Some(f) = fatal {
                if now >= f {
                    self.abandon(src, tag, seq);
                    return Err(Error::Comm(format!(
                        "rank {} timed out after {:?} waiting for (src={src}, tag={tag})",
                        self.rank,
                        self.recv_timeout.unwrap_or_default()
                    )));
                }
            }
            // Sleep until the earliest actionable deadline: the fatal
            // deadline, the retry threshold, or a held message's release.
            let mut wake = fatal;
            if let Some(r) = next_retry {
                wake = Some(wake.map_or(r, |w| w.min(r)));
            }
            if let Some(h) = self.next_fault_release() {
                wake = Some(wake.map_or(h, |w| w.min(h)));
            }
            let outcome = match wake {
                Some(w) => {
                    let dur = w
                        .saturating_duration_since(now)
                        .max(Duration::from_micros(100));
                    self.transport.recv_deadline(dur)
                }
                None => self.transport.recv_blocking(),
            };
            let arrival = match outcome {
                Arrival::Message(msg) => Some(msg),
                Arrival::Timeout => None,
                Arrival::Disconnected => {
                    self.abandon(src, tag, seq);
                    return Err(Error::Comm(format!(
                        "rank {} waiting for (src={src}, tag={tag}) with every peer disconnected",
                        self.rank
                    )));
                }
            };
            if let Some(msg) = arrival {
                self.deliver(msg);
            }
            if let Some(r) = next_retry {
                if Instant::now() >= r {
                    attempt += 1;
                    self.stats.faults.retries += 1;
                    if attempt == 1 {
                        self.stats.faults.stragglers += 1;
                    }
                    if attempt <= self.max_retransmits && self.recover_from_limbo(src, tag) {
                        self.stats.faults.retransmits += 1;
                    }
                    let base = self.retry_timeout.unwrap_or(Duration::from_millis(1));
                    next_retry =
                        Some(Instant::now() + base * 2u32.saturating_pow(attempt.min(6)));
                }
            }
        }
    }

    /// Decode a payload as `T` elements: zero-copy when the typed buffer
    /// matches (owned or pooled), length-checked wire fallback otherwise.
    fn decode_payload<T: Scalar>(&mut self, body: Body) -> Result<Payload<T>> {
        match body {
            Body::Typed(TypedBody {
                wire_size,
                data,
                to_wire,
                ..
            }) => {
                if wire_size == T::WIRE_SIZE {
                    match data.downcast::<Vec<T>>() {
                        Ok(arc) => {
                            self.stats.zero_copy_msgs += 1;
                            return Ok(Payload::Owned(
                                Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone()),
                            ));
                        }
                        Err(data) => match data.downcast::<PooledBody<T>>() {
                            Ok(arc) => {
                                self.stats.zero_copy_msgs += 1;
                                return Ok(Payload::Pooled(arc));
                            }
                            Err(data) => {
                                self.stats.wire_msgs += 1;
                                return parse_wire::<T>(&to_wire(&data)).map(Payload::Owned);
                            }
                        },
                    }
                }
                // Element-size mismatch: the wire fallback's length check
                // reports it (same failure mode as the byte path).
                self.stats.wire_msgs += 1;
                parse_wire::<T>(&to_wire(&data)).map(Payload::Owned)
            }
            Body::Bytes(buf) => {
                self.stats.wire_msgs += 1;
                parse_wire::<T>(&buf).map(Payload::Owned)
            }
        }
    }

    /// Shared completion bookkeeping: block for the matched arrival,
    /// account wait time and traffic, and retire the request slot — also
    /// on the timeout path, where the request is dead either way (leaving
    /// `in_flight` inflated would corrupt the overlap counters).
    fn complete(&mut self, src: usize, tag: u64, seq: u64) -> Result<Body> {
        let t0 = Instant::now();
        let res = self.claim(src, tag, seq);
        self.stats.wait_time_s += t0.elapsed().as_secs_f64();
        self.in_flight -= 1;
        let body = match res {
            Ok(body) => body,
            Err(e) => {
                if self.plan.is_some() {
                    self.plan_record(plan::PlanEvent::RecvTimeout { src, tag, seq });
                }
                return Err(e);
            }
        };
        self.stats.messages_received += 1;
        self.stats.bytes_received += body.wire_len();
        if self.plan.is_some() {
            self.plan_record(plan::PlanEvent::RecvComplete {
                src,
                tag,
                seq,
                bytes: body.wire_len(),
            });
        }
        Ok(body)
    }

    /// Complete a posted receive, blocking until its message arrives, and
    /// take ownership of the contents (a pooled payload is copied out and
    /// returned to its sender). Prefer [`Comm::wait_payload`] on hot paths
    /// that only read the payload.
    pub fn wait<T: Scalar>(&mut self, req: RecvRequest<T>) -> Result<Vec<T>> {
        self.wait_payload(req).map(Payload::into_owned)
    }

    /// Complete a posted receive, blocking until its message arrives,
    /// without taking ownership: the returned [`Payload`] is consumed in
    /// place and its drop returns a registered buffer to the sender's
    /// pool — the receiver half of the pool's recycle cycle.
    pub fn wait_payload<T: Scalar>(&mut self, req: RecvRequest<T>) -> Result<Payload<T>> {
        let body = self.complete(req.src, req.tag, req.seq)?;
        self.decode_with_recovery(req.src, req.tag, req.seq, body)
    }

    /// Decode a matched body; when decoding fails *and* the fault layer
    /// holds the pristine copy of that exact wire sequence (payload
    /// truncation), recover from it — the receiver-side analogue of a
    /// checksum-failure retransmit.
    fn decode_with_recovery<T: Scalar>(
        &mut self,
        src: usize,
        tag: u64,
        seq: u64,
        body: Body,
    ) -> Result<Payload<T>> {
        match self.decode_payload(body) {
            Ok(p) => Ok(p),
            Err(e) => match self.limbo_take(src, tag, seq) {
                Some(pristine) => {
                    self.stats.faults.retransmits += 1;
                    self.decode_payload(pristine)
                }
                None => Err(e),
            },
        }
    }

    /// Complete a batch of posted receives, in order. On the first error
    /// the remaining requests are abandoned (their slots retired) and the
    /// error is returned.
    pub fn wait_all<T: Scalar>(&mut self, reqs: Vec<RecvRequest<T>>) -> Result<Vec<Vec<T>>> {
        let mut out = Vec::with_capacity(reqs.len());
        let mut iter = reqs.into_iter();
        while let Some(req) = iter.next() {
            match self.wait(req) {
                Ok(v) => out.push(v),
                Err(e) => {
                    for r in iter {
                        self.in_flight -= 1;
                        self.abandon(r.src, r.tag, r.seq);
                    }
                    return Err(e);
                }
            }
        }
        Ok(out)
    }

    /// Complete **whichever** posted receive's message is available first
    /// — MPI's `Waitany`. Returns the completed request's index in `reqs`
    /// (at call time) and its payload, removing the request from `reqs`;
    /// callers holding per-request metadata in a parallel `Vec` should
    /// `remove(idx)` from it symmetrically.
    ///
    /// Where [`Comm::wait_all`] drains receives in *post* order — so a
    /// slow first sender stalls the assembly of messages that already
    /// arrived — this drains them in *arrival* order. The nonovertaking
    /// rule still applies per `(source, tag)` stream: a request only
    /// completes once the arrivals it is sequenced behind have been
    /// matched. Gather and all-to-all assembly post distinct
    /// `(source, tag)` pairs, so for them arrival order is unconstrained.
    ///
    /// On timeout every outstanding request in `reqs` is abandoned (their
    /// slots retired, mirroring [`Comm::wait_all`]'s error path) and the
    /// error is returned.
    pub fn wait_any<T: Scalar>(
        &mut self,
        reqs: &mut Vec<RecvRequest<T>>,
    ) -> Result<(usize, Vec<T>)> {
        let (idx, payload) = self.wait_any_payload(reqs)?;
        Ok((idx, payload.into_owned()))
    }

    /// [`Comm::wait_any`] without taking ownership of the payload — the
    /// arrival-order drain the gather and all-to-all assemblies run on,
    /// returning a [`Payload`] whose drop recycles a registered buffer to
    /// its sender.
    pub fn wait_any_payload<T: Scalar>(
        &mut self,
        reqs: &mut Vec<RecvRequest<T>>,
    ) -> Result<(usize, Payload<T>)> {
        if reqs.is_empty() {
            return Err(Error::Comm("wait_any: no posted receives".into()));
        }
        let t0 = Instant::now();
        let fatal = self.recv_timeout.map(|d| t0 + d);
        let mut attempt: u32 = 0;
        let mut next_retry = self.retry_timeout.map(|d| t0 + d);
        loop {
            self.pump();
            let keys: Vec<(usize, u64)> = reqs.iter().map(|r| (r.src, r.tag)).collect();
            for (src, tag) in keys {
                while self.promote_parked(src, tag) {}
            }
            if let Some(idx) = reqs
                .iter()
                .position(|r| self.ready.contains_key(&(r.src, r.tag, r.seq)))
            {
                let req = reqs.remove(idx);
                let body = self
                    .ready
                    .remove(&(req.src, req.tag, req.seq))
                    .expect("readiness probed above");
                let stall = t0.elapsed().as_secs_f64();
                if stall > self.stats.faults.max_stall_s {
                    self.stats.faults.max_stall_s = stall;
                }
                self.stats.wait_time_s += stall;
                self.in_flight -= 1;
                self.stats.messages_received += 1;
                self.stats.bytes_received += body.wire_len();
                if self.plan.is_some() {
                    self.plan_record(plan::PlanEvent::RecvComplete {
                        src: req.src,
                        tag: req.tag,
                        seq: req.seq,
                        bytes: body.wire_len(),
                    });
                }
                let payload = self.decode_with_recovery(req.src, req.tag, req.seq, body)?;
                return Ok((idx, payload));
            }
            let now = Instant::now();
            let fatal_hit = fatal.is_some_and(|f| now >= f);
            let disconnected = if fatal_hit {
                false
            } else {
                // Sleep until the earliest actionable deadline: the fatal
                // deadline, the retry threshold, or a held message's
                // release; with no deadlines at all, block indefinitely.
                let mut wake = fatal;
                if let Some(r) = next_retry {
                    wake = Some(wake.map_or(r, |w| w.min(r)));
                }
                if let Some(h) = self.next_fault_release() {
                    wake = Some(wake.map_or(h, |w| w.min(h)));
                }
                let outcome = match wake {
                    Some(w) => {
                        let dur = w
                            .saturating_duration_since(now)
                            .max(Duration::from_micros(100));
                        self.transport.recv_deadline(dur)
                    }
                    None => self.transport.recv_blocking(),
                };
                match outcome {
                    Arrival::Message(msg) => {
                        self.deliver(msg);
                        false
                    }
                    Arrival::Timeout => false,
                    Arrival::Disconnected => true,
                }
            };
            if fatal_hit || disconnected {
                self.stats.wait_time_s += t0.elapsed().as_secs_f64();
                let outstanding = reqs.len();
                for r in reqs.drain(..) {
                    self.in_flight -= 1;
                    if self.plan.is_some() {
                        self.plan_record(plan::PlanEvent::RecvTimeout {
                            src: r.src,
                            tag: r.tag,
                            seq: r.seq,
                        });
                    }
                    self.abandon(r.src, r.tag, r.seq);
                }
                return Err(Error::Comm(if disconnected {
                    format!(
                        "rank {} in wait_any with {outstanding} receives outstanding and every peer disconnected",
                        self.rank
                    )
                } else {
                    format!(
                        "rank {} timed out after {:?} in wait_any with {outstanding} receives outstanding",
                        self.rank,
                        self.recv_timeout.unwrap_or_default()
                    )
                }));
            }
            if let Some(r) = next_retry {
                if Instant::now() >= r {
                    attempt += 1;
                    self.stats.faults.retries += 1;
                    if attempt == 1 {
                        self.stats.faults.stragglers += 1;
                    }
                    if attempt <= self.max_retransmits {
                        // Ask every distinct stream with an outstanding
                        // request for one retransmit.
                        let mut streams: Vec<(usize, u64)> =
                            reqs.iter().map(|r| (r.src, r.tag)).collect();
                        streams.sort_unstable();
                        streams.dedup();
                        for (src, tag) in streams {
                            if self.recover_from_limbo(src, tag) {
                                self.stats.faults.retransmits += 1;
                            }
                        }
                    }
                    let base = self.retry_timeout.unwrap_or(Duration::from_millis(1));
                    next_retry =
                        Some(Instant::now() + base * 2u32.saturating_pow(attempt.min(6)));
                }
            }
        }
    }

    /// Nonblocking probe: has the message for `req` already arrived?
    /// Never blocks; a `true` result means `wait` will return immediately.
    pub fn test<T: Scalar>(&mut self, req: &RecvRequest<T>) -> bool {
        self.pump();
        while self.promote_parked(req.src, req.tag) {}
        self.ready.contains_key(&(req.src, req.tag, req.seq))
    }

    /// Blocking receive of the next message from `src` with `tag`,
    /// returned as wire-format bytes (typed messages are serialized on
    /// demand — the interop fallback).
    pub fn recv_bytes(&mut self, src: usize, tag: u64) -> Result<Vec<u8>> {
        let req = self.irecv_as::<f64>(src, tag, "bytes")?; // element type irrelevant here
        let body = self.complete(req.src, req.tag, req.seq)?;
        self.stats.wire_msgs += 1;
        match body {
            Body::Bytes(buf) => Ok(buf),
            Body::Typed(t) => Ok((t.to_wire)(&t.data)),
        }
    }

    /// Blocking receive of a typed vector; errors if the payload's element
    /// type or length disagrees.
    pub fn recv_vec<T: Scalar>(&mut self, src: usize, tag: u64) -> Result<Vec<T>> {
        let req = self.irecv::<T>(src, tag)?;
        self.wait(req)
    }

    /// Exchange slices with a peer: post both directions, then complete
    /// the receive. The building block of the halo exchange operator C_E.
    pub fn sendrecv<T: Scalar>(
        &mut self,
        peer: usize,
        send_tag: u64,
        recv_tag: u64,
        data: &[T],
    ) -> Result<Vec<T>> {
        let s = self.isend_slice(peer, send_tag, data)?;
        let r = self.irecv::<T>(peer, recv_tag)?;
        self.wait_send(s)?;
        self.wait(r)
    }

    /// Full-world barrier.
    ///
    /// The in-process backend cannot fail here. A socket backend can — a
    /// peer dying mid-barrier — and that is exactly as fatal as a rank
    /// panicking, so the failure propagates as a panic and the cluster
    /// launcher reports which rank fell over, the same way it reports
    /// every other unrecoverable teardown.
    pub fn barrier(&mut self) {
        if let Some(h) = &self.plan {
            if let Ok(mut g) = h.lock() {
                let index = g.next_barrier();
                g.record(plan::PlanEvent::Barrier { index });
            }
        }
        if let Err(e) = self.transport.barrier() {
            panic!("rank {} barrier failed: {e}", self.rank);
        }
    }

    /// Which transport backend this endpoint runs over (`"channel"`,
    /// `"tcp"`, or `"unix"`).
    pub fn transport_kind(&self) -> &'static str {
        self.transport.kind()
    }
}

/// An ordered subset of world ranks acting as one communicator axis.
///
/// The hybrid data×model topology factors the world into
/// `replicas × model-grid`; each axis is a `CommGroup` produced by
/// [`CommGroup::split`] — the MPI `Comm_split` idiom (colour selects the
/// group, key orders it) applied to the existing endpoint map. A group
/// owns no channels: members keep addressing each other by **world rank**
/// through their [`Comm`] endpoints, so any primitive that takes a rank
/// list (the broadcast/sum-reduce trees, the ring collectives) runs
/// unchanged inside a group. Group-local indices (`index_of` /
/// `world_rank`) are what schedules like the ring's neighbour arithmetic
/// are written against.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommGroup {
    ranks: Vec<usize>,
}

impl CommGroup {
    /// A group over the given world ranks, in the given order. Ranks must
    /// be distinct; the first rank is group index 0.
    pub fn new(ranks: Vec<usize>) -> Result<Self> {
        if ranks.is_empty() {
            return Err(Error::Comm("communicator group must be non-empty".into()));
        }
        let mut seen = ranks.clone();
        seen.sort_unstable();
        if seen.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Comm(format!(
                "communicator group has duplicate ranks: {ranks:?}"
            )));
        }
        Ok(CommGroup { ranks })
    }

    /// Partition `0..world` into groups, MPI `Comm_split` style: ranks
    /// with equal `color` land in the same group (a `None` colour opts
    /// the rank out of every group), ordered within the group by
    /// `(key, world rank)`. Groups are returned ordered by colour.
    pub fn split(
        world: usize,
        mut color: impl FnMut(usize) -> Option<usize>,
        mut key: impl FnMut(usize) -> usize,
    ) -> Vec<CommGroup> {
        let mut by_color: std::collections::BTreeMap<usize, Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for rank in 0..world {
            if let Some(c) = color(rank) {
                by_color.entry(c).or_default().push((key(rank), rank));
            }
        }
        by_color
            .into_values()
            .map(|mut members| {
                members.sort_unstable();
                CommGroup {
                    ranks: members.into_iter().map(|(_, r)| r).collect(),
                }
            })
            .collect()
    }

    /// Number of members.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// The members' world ranks in group order.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// World rank of group member `index`.
    pub fn world_rank(&self, index: usize) -> usize {
        self.ranks[index]
    }

    /// Group index of `world_rank`, if it is a member.
    pub fn index_of(&self, world_rank: usize) -> Option<usize> {
        self.ranks.iter().position(|&r| r == world_rank)
    }

    /// Whether `world_rank` is a member.
    pub fn contains(&self, world_rank: usize) -> bool {
        self.index_of(world_rank).is_some()
    }
}

/// Environment-derived endpoint configuration, resolved once per cluster
/// launch (or once per process for [`Cluster::connect_from_env`]) so
/// every rank sees identical knobs.
pub(crate) struct EndpointConfig {
    recv_timeout: Option<Duration>,
    retry_timeout: Option<Duration>,
    max_retransmits: u32,
    pool_cap: Option<usize>,
    fault_plan: Option<FaultPlan>,
}

impl EndpointConfig {
    pub(crate) fn from_env() -> Self {
        EndpointConfig {
            recv_timeout: configured_recv_timeout(),
            retry_timeout: configured_retry_timeout(),
            max_retransmits: configured_max_retransmits(),
            pool_cap: configured_comm_pool_cap(),
            fault_plan: faults::configured_fault_plan(),
        }
    }
}

impl Comm {
    /// Wrap a connected transport in a fully-wired endpoint.
    pub(crate) fn assemble(transport: Box<dyn Transport>, cfg: &EndpointConfig) -> Comm {
        let mut comm = Comm {
            rank: transport.rank(),
            size: transport.world(),
            transport,
            parked: HashMap::new(),
            ready: HashMap::new(),
            next_posted: HashMap::new(),
            next_arrived: HashMap::new(),
            next_send: HashMap::new(),
            next_wire: HashMap::new(),
            ooo: HashMap::new(),
            discard: HashSet::new(),
            in_flight: 0,
            wire_format: false,
            pool: BufferPool::new(cfg.pool_cap),
            recv_timeout: cfg.recv_timeout,
            retry_timeout: cfg.retry_timeout,
            max_retransmits: cfg.max_retransmits,
            faults: None,
            plan: None,
            stats: CommStats::default(),
        };
        if let Some(plan) = cfg.fault_plan.clone() {
            comm.set_fault_plan(Some(plan));
        }
        comm
    }

    /// Wrap an already-connected [`Transport`] in an endpoint configured
    /// from the environment (timeouts, retransmit bound, pool cap, fault
    /// plan) — the entry point for processes that built their transport
    /// by hand rather than through [`Cluster`].
    pub fn over(transport: Box<dyn Transport>) -> Comm {
        Comm::assemble(transport, &EndpointConfig::from_env())
    }
}

/// An SPMD cluster: `world` ranks running the same closure.
///
/// With the default [`TransportKind::Channel`] backend the ranks are
/// scoped threads wired by an in-process channel mesh. With a socket
/// backend ([`TransportKind::Unix`]/[`TransportKind::Tcp`]) the ranks are
/// still threads here, but every byte crosses a real OS socket — and the
/// same bootstrap lets `world` separate *processes* form a cluster via
/// [`Cluster::connect_from_env`].
pub struct Cluster;

impl Cluster {
    /// Run `f` on `world` ranks concurrently and collect per-rank results
    /// in rank order, over the ambient transport
    /// ([`default_transport`]: a [`TransportGuard`](super::TransportGuard)
    /// override, else `PALLAS_TRANSPORT`, else the channel backend).
    ///
    /// `f` may borrow from the caller (scoped threads). Worker panics are
    /// converted into `Error::Comm` naming the rank.
    pub fn run<R, F>(world: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&mut Comm) -> Result<R> + Send + Sync,
    {
        Cluster::run_on(default_transport(), world, f)
    }

    /// [`Cluster::run`] over an explicit transport backend.
    pub fn run_on<R, F>(kind: TransportKind, world: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&mut Comm) -> Result<R> + Send + Sync,
    {
        if world == 0 {
            return Err(Error::Comm("world size must be >= 1".into()));
        }
        let cfg = EndpointConfig::from_env();
        match kind {
            TransportKind::Channel => {
                let mut comms: Vec<Comm> = ChannelTransport::mesh(world)
                    .into_iter()
                    .map(|t| Comm::assemble(Box::new(t), &cfg))
                    .collect();
                let f = &f;
                let results: Vec<Result<R>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = comms
                        .iter_mut()
                        .map(|comm| scope.spawn(move || f(comm)))
                        .collect();
                    Cluster::collect(handles)
                });
                results.into_iter().collect()
            }
            TransportKind::Tcp | TransportKind::Unix => {
                // Bind the coordinator listener *before* spawning so no
                // rank can race it (and, for TCP, so the kernel picks a
                // free port that rank 0 then actually owns).
                let coord = SocketTransport::reserve_coord(kind)?;
                let cfg = &cfg;
                let f = &f;
                let coord = &coord;
                let results: Vec<Result<R>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..world)
                        .map(|rank| {
                            scope.spawn(move || {
                                let t =
                                    SocketTransport::connect_reserved(kind, world, rank, coord)?;
                                let mut comm = Comm::assemble(Box::new(t), cfg);
                                f(&mut comm)
                            })
                        })
                        .collect();
                    Cluster::collect(handles)
                });
                results.into_iter().collect()
            }
        }
    }

    fn collect<R>(
        handles: Vec<std::thread::ScopedJoinHandle<'_, Result<R>>>,
    ) -> Vec<Result<R>> {
        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| match h.join() {
                Ok(r) => r,
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "worker panicked".into());
                    Err(Error::Comm(format!("rank {rank} panicked: {msg}")))
                }
            })
            .collect()
    }

    /// Like [`Cluster::run`], returning per-rank [`CommStats`] alongside
    /// the results.
    pub fn run_with_stats<R, F>(world: usize, f: F) -> Result<Vec<(R, CommStats)>>
    where
        R: Send,
        F: Fn(&mut Comm) -> Result<R> + Send + Sync,
    {
        Cluster::run(world, |comm| {
            let r = f(comm)?;
            Ok((r, comm.stats()))
        })
    }

    /// Join a multi-**process** cluster as a single rank.
    ///
    /// Reads `PALLAS_TRANSPORT` (must be a socket backend),
    /// `PALLAS_WORLD`, `PALLAS_RANK`, and `PALLAS_COORD_ADDR`, runs the
    /// socket bootstrap against the coordinator at that address, and
    /// returns this process's fully-connected endpoint. Every process of
    /// the job calls this once; rank 0's process implicitly acts as the
    /// coordinator.
    pub fn connect_from_env() -> Result<Comm> {
        let kind = default_transport();
        if kind == TransportKind::Channel {
            return Err(Error::Config(format!(
                "{}=channel cannot span OS processes; set tcp or unix",
                crate::util::env::TRANSPORT_ENV
            )));
        }
        let world = crate::util::env::configured_world().ok_or_else(|| {
            Error::Config(format!(
                "{} must be set to join a multi-process cluster",
                crate::util::env::WORLD_ENV
            ))
        })?;
        let rank = crate::util::env::configured_rank(world).ok_or_else(|| {
            Error::Config(format!(
                "{} must be set (0 <= rank < {world}) to join a multi-process cluster",
                crate::util::env::RANK_ENV
            ))
        })?;
        let coord = crate::util::env::configured_coord_addr().ok_or_else(|| {
            Error::Config(format!(
                "{} must be set to join a multi-process cluster",
                crate::util::env::COORD_ADDR_ENV
            ))
        })?;
        let t = SocketTransport::connect(kind, world, rank, &coord)?;
        Ok(Comm::over(Box::new(t)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = Cluster::run(4, |comm| {
            let next = (comm.rank() + 1) % comm.size();
            let prev = (comm.rank() + comm.size() - 1) % comm.size();
            comm.send_slice::<f64>(next, 1, &[comm.rank() as f64])?;
            let got = comm.recv_vec::<f64>(prev, 1)?;
            Ok(got[0])
        })
        .unwrap();
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn single_rank_world() {
        let r = Cluster::run(1, |comm| {
            assert_eq!(comm.size(), 1);
            Ok(comm.rank())
        })
        .unwrap();
        assert_eq!(r, vec![0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        // Rank 0 sends tag 2 then tag 1; rank 1 receives tag 1 first.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 2, &[20.0])?;
                comm.send_slice::<f64>(1, 1, &[10.0])?;
                Ok(0.0)
            } else {
                let a = comm.recv_vec::<f64>(0, 1)?[0];
                let b = comm.recv_vec::<f64>(0, 2)?[0];
                Ok(a * 1000.0 + b)
            }
        })
        .unwrap();
        assert_eq!(results[1], 10020.0);
    }

    #[test]
    fn fifo_within_same_tag() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                for i in 0..5 {
                    comm.send_slice::<f64>(1, 7, &[i as f64])?;
                }
                Ok(vec![])
            } else {
                let mut got = Vec::new();
                for _ in 0..5 {
                    got.push(comm.recv_vec::<f64>(0, 7)?[0]);
                }
                Ok(got)
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sendrecv_exchange() {
        let results = Cluster::run(2, |comm| {
            let peer = 1 - comm.rank();
            let mine = [comm.rank() as f32 + 1.0];
            let theirs = comm.sendrecv(peer, 9, 9, &mine)?;
            Ok(theirs[0])
        })
        .unwrap();
        assert_eq!(results, vec![2.0, 1.0]);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        Cluster::run(4, |comm| {
            counter.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            // after the barrier every rank must see all increments
            assert_eq!(counter.load(Ordering::SeqCst), 4);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn worker_panic_is_reported() {
        let err = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                panic!("boom");
            }
            Ok(())
        })
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("rank 1") && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn send_out_of_range_errors() {
        let res = Cluster::run(1, |comm| comm.send_slice::<f32>(5, 0, &[1.0]));
        assert!(res.is_err());
    }

    #[test]
    fn stats_count_traffic() {
        let out = Cluster::run_with_stats(2, |comm| {
            let peer = 1 - comm.rank();
            comm.send_slice::<f64>(peer, 3, &[1.0, 2.0, 3.0])?;
            let _ = comm.recv_vec::<f64>(peer, 3)?;
            Ok(())
        })
        .unwrap();
        for (_, s) in out {
            assert_eq!(s.messages_sent, 1);
            assert_eq!(s.messages_received, 1);
            assert_eq!(s.bytes_sent, 8 + 24);
            // the typed path never touched the wire format
            assert_eq!(s.zero_copy_msgs, 1);
            assert_eq!(s.wire_msgs, 0);
        }
    }

    #[test]
    fn typed_wire_integrity() {
        // Sending f64 but receiving f32 must fail the length check.
        let res = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 4, &[1.0, 2.0, 3.0])?;
                Ok(())
            } else {
                match comm.recv_vec::<f32>(0, 4) {
                    Err(Error::Comm(_)) => Ok(()),
                    other => panic!("expected comm error, got {other:?}"),
                }
            }
        });
        assert!(res.is_ok());
    }

    #[test]
    fn irecv_matches_post_order_not_wait_order() {
        // FIFO-per-(src, tag): request k gets message k even when the
        // requests are completed in reverse order.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                for i in 0..6 {
                    comm.send_slice::<f64>(1, 11, &[i as f64])?;
                }
                Ok(vec![])
            } else {
                let mut reqs = Vec::new();
                for _ in 0..6 {
                    reqs.push(comm.irecv::<f64>(0, 11)?);
                }
                let mut got = vec![0.0; 6];
                for (k, req) in reqs.into_iter().enumerate().rev() {
                    got[k] = comm.wait(req)?[0];
                }
                Ok(got)
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn test_probe_is_nonblocking() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.barrier(); // rank 1 probes before anything is sent
                comm.send_slice::<f64>(1, 5, &[42.0])?;
                Ok(0.0)
            } else {
                let req = comm.irecv::<f64>(0, 5)?;
                assert!(!comm.test(&req), "probe true before send");
                comm.barrier();
                // spin until the message lands, then complete
                while !comm.test(&req) {
                    std::thread::yield_now();
                }
                Ok(comm.wait(req)?[0])
            }
        })
        .unwrap();
        assert_eq!(results[1], 42.0);
    }

    #[test]
    fn wait_any_drains_in_arrival_order() {
        // Rank 0 posts receives from ranks 1..4 on distinct tags, then
        // releases the senders one at a time in reverse rank order (3, 2,
        // 1) with a "go" token, completing one wait_any between releases.
        // Each wait_any must surface the one sender that was released —
        // i.e. completion follows arrival order, not the post order the
        // requests were issued in.
        let results = Cluster::run(4, |comm| {
            if comm.rank() == 0 {
                let mut reqs: Vec<RecvRequest<f64>> = Vec::new();
                let mut srcs = Vec::new();
                for src in 1..4usize {
                    reqs.push(comm.irecv::<f64>(src, 40 + src as u64)?);
                    srcs.push(src);
                }
                let mut order = Vec::new();
                for release in [3usize, 2, 1] {
                    comm.send_slice::<f64>(release, 90, &[0.0])?;
                    let (idx, data) = comm.wait_any(&mut reqs)?;
                    let src = srcs.remove(idx);
                    assert_eq!(src, release, "wait_any surfaced the wrong sender");
                    assert_eq!(data[0] as usize, src);
                    order.push(src);
                }
                assert!(reqs.is_empty());
                assert_eq!(comm.in_flight(), 0);
                Ok(order)
            } else {
                let _ = comm.recv_vec::<f64>(0, 90)?;
                comm.send_slice::<f64>(0, 40 + comm.rank() as u64, &[comm.rank() as f64])?;
                Ok(vec![])
            }
        })
        .unwrap();
        assert_eq!(results[0], vec![3, 2, 1]);
    }

    #[test]
    fn wait_any_respects_nonovertaking_per_stream() {
        // Two receives on the same (source, tag): the first-posted request
        // must get the first-sent payload even when completed via wait_any.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 7, &[10.0])?;
                comm.send_slice::<f64>(1, 7, &[20.0])?;
                Ok(vec![])
            } else {
                let mut reqs = vec![comm.irecv::<f64>(0, 7)?, comm.irecv::<f64>(0, 7)?];
                let (i1, d1) = comm.wait_any(&mut reqs)?;
                let (i2, d2) = comm.wait_any(&mut reqs)?;
                assert_eq!((i1, i2), (0, 0));
                Ok(vec![d1[0], d2[0]])
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![10.0, 20.0]);
    }

    #[test]
    fn wait_any_on_empty_set_errors() {
        Cluster::run(1, |comm| {
            let mut reqs: Vec<RecvRequest<f64>> = Vec::new();
            assert!(comm.wait_any(&mut reqs).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn wait_all_completes_batch() {
        let results = Cluster::run(3, |comm| {
            if comm.rank() == 0 {
                let mut reqs = Vec::new();
                for src in 1..3 {
                    comm.send_slice::<f64>(src, 2, &[src as f64])?;
                    reqs.push(comm.irecv::<f64>(src, 3)?);
                }
                let got = comm.wait_all(reqs)?;
                Ok(got.into_iter().map(|v| v[0]).sum::<f64>())
            } else {
                let v = comm.recv_vec::<f64>(0, 2)?;
                comm.send_slice::<f64>(0, 3, &[v[0] * 10.0])?;
                Ok(0.0)
            }
        })
        .unwrap();
        assert_eq!(results[0], 30.0); // 10 + 20
    }

    #[test]
    fn wire_format_roundtrips() {
        let results = Cluster::run(2, |comm| {
            comm.set_wire_format(true);
            let peer = 1 - comm.rank();
            let mine = [comm.rank() as f64 + 0.5, -1.0];
            let theirs = comm.sendrecv(peer, 9, 9, &mine)?;
            assert!(comm.stats().wire_msgs >= 1);
            assert_eq!(comm.stats().zero_copy_msgs, 0);
            Ok(theirs[0])
        })
        .unwrap();
        assert_eq!(results, vec![1.5, 0.5]);
    }

    #[test]
    fn recv_bytes_serializes_typed_payloads() {
        // The raw-bytes API keeps working when the sender used the typed
        // path: the message is serialized on demand.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f32>(1, 8, &[1.0, 2.0])?;
                Ok(vec![])
            } else {
                let buf = comm.recv_bytes(0, 8)?;
                Ok(parse_wire::<f32>(&buf)?)
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![1.0, 2.0]);
    }

    #[test]
    fn in_flight_counters_track_requests() {
        let out = Cluster::run_with_stats(2, |comm| {
            let peer = 1 - comm.rank();
            for i in 0..4 {
                comm.send_slice::<f64>(peer, 20 + i, &[i as f64])?;
            }
            let reqs: Vec<_> = (0..4)
                .map(|i| comm.irecv::<f64>(peer, 20 + i))
                .collect::<Result<_>>()?;
            assert_eq!(comm.in_flight(), 4);
            comm.wait_all(reqs)?;
            assert_eq!(comm.in_flight(), 0);
            Ok(())
        })
        .unwrap();
        for (_, s) in out {
            assert_eq!(s.irecvs_posted, 4);
            assert_eq!(s.max_in_flight, 4);
        }
    }

    #[test]
    fn shared_send_fans_out_without_copies() {
        let results = Cluster::run(3, |comm| {
            if comm.rank() == 0 {
                let buf = Arc::new(vec![7.0f64, 8.0]);
                for dst in 1..3 {
                    comm.send_shared(dst, 6, &buf)?;
                }
                Ok(0.0)
            } else {
                Ok(comm.recv_vec::<f64>(0, 6)?[1])
            }
        })
        .unwrap();
        assert_eq!(results[1], 8.0);
        assert_eq!(results[2], 8.0);
    }

    #[test]
    fn pooled_send_returns_buffer_to_sender() {
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None); // immune to env caps in CI legs
            if comm.rank() == 0 {
                let mut buf = comm.pool_take::<f64>(16);
                buf.iter_mut().enumerate().for_each(|(i, v)| *v = i as f64);
                let req = comm.isend_pooled(1, 5, buf)?;
                comm.wait_send(req)?;
                comm.barrier(); // receiver has consumed and dropped
                let again = comm.pool_take::<f64>(16);
                assert_eq!(again.len(), 16);
                let s = comm.pool_stats();
                assert_eq!(s.acquires, 2);
                assert_eq!(s.misses, 1, "second take must be served by the return");
                assert_eq!(s.hits, 1);
                assert_eq!(s.returns, 1);
                assert_eq!(s.evictions, 0);
            } else {
                let req = comm.irecv::<f64>(0, 5)?;
                let payload = comm.wait_payload(req)?;
                assert!(matches!(payload, Payload::Pooled(_)));
                assert_eq!(payload.as_slice()[15], 15.0);
                drop(payload); // the return
                comm.barrier();
                // the receiver's own pool saw no traffic
                assert_eq!(comm.pool_stats().acquires, 0);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pool_reserve_prewarms_rotation_depth_on_second_miss() {
        Cluster::run(1, |comm| {
            comm.set_pool_cap_bytes(None);
            comm.pool_reserve(3);
            // First miss of a class mints on demand only (a depth-1 class
            // stops here and never parks dead extras)...
            let a = comm.pool_take::<f64>(8);
            let s = comm.pool_stats();
            assert_eq!((s.misses, s.reserved), (1, 0));
            // ...the second concurrent take proves the class is pipelined
            // and pre-warms the rest of the rotation depth...
            let b = comm.pool_take::<f64>(8);
            let s = comm.pool_stats();
            assert_eq!((s.misses, s.reserved), (2, 1));
            // ...so the third concurrent take hits the parked extra.
            let c = comm.pool_take::<f64>(8);
            let s = comm.pool_stats();
            assert_eq!(s.acquires, 3);
            assert_eq!(s.misses, 2, "the pre-warmed take must hit");
            assert_eq!(s.hits, 1);
            assert_eq!((a.len(), b.len(), c.len()), (8, 8, 8));
            // A hard cap suppresses the eager mints (nothing is evicted —
            // the extras are simply not minted).
            comm.set_pool_cap_bytes(Some(1));
            let _d = comm.pool_take::<f64>(16); // first miss: marks only
            let _e = comm.pool_take::<f64>(16); // second miss: extras blocked
            let s = comm.pool_stats();
            assert_eq!(s.misses, 4);
            assert_eq!(s.reserved, 1, "capped pool must not park extras");
            assert_eq!(s.evictions, 0);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pool_reserve_for_overrides_one_class_only() {
        Cluster::run(1, |comm| {
            comm.set_pool_cap_bytes(None);
            comm.pool_reserve(1); // global default: mint on demand
            comm.pool_reserve_for::<f64>(8, 3);
            // The overridden class pre-warms to depth 3 on its second miss...
            let _a = comm.pool_take::<f64>(8);
            let _b = comm.pool_take::<f64>(8);
            let s = comm.pool_stats();
            assert_eq!((s.misses, s.reserved), (2, 1));
            let _c = comm.pool_take::<f64>(8);
            assert_eq!(comm.pool_stats().hits, 1, "pre-warmed extra must serve");
            // ...while any other class keeps the depth-1 default.
            let _d = comm.pool_take::<f64>(16);
            let _e = comm.pool_take::<f64>(16);
            let s = comm.pool_stats();
            assert_eq!(s.reserved, 1, "non-overridden class must not pre-warm");
            // Depth <= 1 removes the override.
            comm.pool_reserve_for::<f64>(8, 1);
            let _f = comm.pool_take::<f64>(8);
            let _g = comm.pool_take::<f64>(8);
            assert_eq!(comm.pool_stats().reserved, 1);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pool_wrap_adopts_buffer_and_returns_on_drop() {
        Cluster::run(1, |comm| {
            comm.set_pool_cap_bytes(None);
            let mut buf = comm.pool_take::<f32>(4);
            buf.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            let body = comm.pool_wrap(buf);
            assert_eq!(body.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
            drop(body);
            let s = comm.pool_stats();
            assert_eq!(s.returns, 1, "wrapped buffer must return to the pool");
            // The returned buffer is reusable: the next take of the class hits.
            let _again = comm.pool_take::<f32>(4);
            assert_eq!(comm.pool_stats().hits, 1);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn comm_group_split_colors_and_orders() {
        // 2 replicas × model grid of 3: colour by model rank = dp axis.
        let dp = CommGroup::split(6, |r| Some(r % 3), |r| r / 3);
        assert_eq!(dp.len(), 3);
        assert_eq!(dp[0].ranks(), &[0, 3]);
        assert_eq!(dp[1].ranks(), &[1, 4]);
        assert_eq!(dp[2].ranks(), &[2, 5]);
        assert_eq!(dp[1].index_of(4), Some(1));
        assert_eq!(dp[1].world_rank(0), 1);
        assert!(!dp[1].contains(3));
        // Colour by replica = model axis; a None colour opts out.
        let model = CommGroup::split(6, |r| (r != 5).then_some(r / 3), |r| r % 3);
        assert_eq!(model[0].ranks(), &[0, 1, 2]);
        assert_eq!(model[1].ranks(), &[3, 4]);
        // The key reorders within a group.
        let rev = CommGroup::split(4, |_| Some(0), |r| 4 - r);
        assert_eq!(rev[0].ranks(), &[3, 2, 1, 0]);
        // Duplicate ranks are rejected by the direct constructor.
        assert!(CommGroup::new(vec![1, 2, 1]).is_err());
        assert!(CommGroup::new(vec![]).is_err());
    }

    #[test]
    fn payload_into_tensor_wraps_without_copy() {
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            if comm.rank() == 0 {
                let mut stage = comm.pool_take::<f32>(4);
                stage.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
                let req = comm.isend_pooled(1, 21, stage)?;
                comm.wait_send(req)?;
                comm.barrier();
                assert_eq!(comm.pool_stats().returns, 1);
            } else {
                let req = comm.irecv::<f32>(0, 21)?;
                let t = comm.wait_payload(req)?.into_tensor(&[2, 2])?;
                assert!(t.is_pool_backed());
                assert_eq!(t.at(&[1, 1]), 4.0);
                drop(t); // the return
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pool_cap_evicts_returns() {
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(Some(1)); // nothing fits
            if comm.rank() == 0 {
                let buf = comm.pool_take::<f32>(8);
                let req = comm.isend_pooled(1, 6, buf)?;
                comm.wait_send(req)?;
                comm.barrier();
                let _again = comm.pool_take::<f32>(8);
                let s = comm.pool_stats();
                assert_eq!(s.returns, 1);
                assert_eq!(s.evictions, 1, "over-cap return must be dropped");
                assert_eq!(s.hits, 0);
                assert_eq!(s.misses, 2);
                assert_eq!(s.pooled_bytes, 0);
            } else {
                let req = comm.irecv::<f32>(0, 6)?;
                let _ = comm.wait_payload(req)?;
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn disabled_pool_degrades_to_move_semantics() {
        Cluster::run(2, |comm| {
            comm.set_comm_pool(false);
            if comm.rank() == 0 {
                let buf = comm.pool_take::<f64>(4);
                let req = comm.isend_pooled(1, 7, buf)?;
                comm.wait_send(req)?;
                assert_eq!(comm.pool_stats().acquires, 0, "disabled pool counted");
            } else {
                let req = comm.irecv::<f64>(0, 7)?;
                let payload = comm.wait_payload(req)?;
                assert!(matches!(payload, Payload::Owned(_)));
                assert_eq!(payload.len(), 4);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pooled_send_under_wire_format_returns_immediately() {
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            comm.set_wire_format(true);
            if comm.rank() == 0 {
                let mut buf = comm.pool_take::<f64>(3);
                buf.copy_from_slice(&[1.0, 2.0, 3.0]);
                let req = comm.isend_pooled(1, 8, buf)?;
                comm.wait_send(req)?;
                // the staging buffer came home without a receiver round trip
                let _again = comm.pool_take::<f64>(3);
                let s = comm.pool_stats();
                assert_eq!(s.returns, 1);
                assert_eq!(s.hits, 1);
            } else {
                let got = comm.recv_vec::<f64>(0, 8)?;
                assert_eq!(got, vec![1.0, 2.0, 3.0]);
                assert!(comm.stats().wire_msgs >= 1);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn shared_pooled_body_fans_out_and_returns_once() {
        // One staged buffer broadcast to two receivers: both read it, the
        // last drop returns it to the root exactly once.
        Cluster::run(3, |comm| {
            comm.set_pool_cap_bytes(None);
            if comm.rank() == 0 {
                let body = comm.pool_stage(&[7.0f64, 8.0]);
                for dst in 1..3 {
                    let req = comm.isend_pooled_body(dst, 9, &body)?;
                    comm.wait_send(req)?;
                }
                drop(body);
                comm.barrier();
                let s = comm.pool_stats();
                assert_eq!(s.returns, 1, "fan-out must return exactly once");
            } else {
                let req = comm.irecv::<f64>(0, 9)?;
                let payload = comm.wait_payload(req)?;
                assert_eq!(payload.as_slice(), &[7.0, 8.0]);
                drop(payload);
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn comm_pool_cap_parsing() {
        assert_eq!(parse_comm_pool_cap(None), Some(DEFAULT_COMM_POOL_CAP_BYTES));
        assert_eq!(
            parse_comm_pool_cap(Some("junk")),
            Some(DEFAULT_COMM_POOL_CAP_BYTES)
        );
        assert_eq!(
            parse_comm_pool_cap(Some("")),
            Some(DEFAULT_COMM_POOL_CAP_BYTES)
        );
        assert_eq!(parse_comm_pool_cap(Some("0")), None);
        assert_eq!(parse_comm_pool_cap(Some(" 4096 ")), Some(4096));
    }

    #[test]
    fn timeout_parsing() {
        assert_eq!(
            parse_recv_timeout(None),
            Some(Duration::from_millis(DEFAULT_RECV_TIMEOUT_MS))
        );
        assert_eq!(
            parse_recv_timeout(Some("250")),
            Some(Duration::from_millis(250))
        );
        assert_eq!(
            parse_recv_timeout(Some(" 1500 ")),
            Some(Duration::from_millis(1500))
        );
        // garbage falls back to the default
        assert_eq!(
            parse_recv_timeout(Some("nope")),
            Some(Duration::from_millis(DEFAULT_RECV_TIMEOUT_MS))
        );
        // 0 means "no timeout" — the uncapped convention shared with the
        // scratch and comm-pool byte caps.
        assert_eq!(parse_recv_timeout(Some("0")), None);
        // the test build uses the short default so deadlocks fail fast
        assert_eq!(DEFAULT_RECV_TIMEOUT_MS, 5_000);

        assert_eq!(
            parse_retry_timeout(None),
            Some(Duration::from_millis(DEFAULT_RETRY_TIMEOUT_MS))
        );
        assert_eq!(
            parse_retry_timeout(Some("40")),
            Some(Duration::from_millis(40))
        );
        assert_eq!(parse_retry_timeout(Some("0")), None);
        assert_eq!(parse_max_retransmits(None), DEFAULT_MAX_RETRANSMITS);
        assert_eq!(parse_max_retransmits(Some("3")), 3);
        assert_eq!(parse_max_retransmits(Some("bad")), DEFAULT_MAX_RETRANSMITS);
    }

    #[test]
    fn resequencer_suppresses_duplicates_and_restores_order() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.set_fault_plan(Some(
                    faults::FaultPlan::parse("seed=3;retry_ms=5;dup:p=1,src=1").unwrap(),
                ));
                let mut got = Vec::new();
                for _ in 0..6 {
                    got.push(comm.recv_vec::<f64>(1, 9)?[0]);
                }
                let s = comm.stats();
                assert!(s.faults.injected_dups >= 6);
                assert!(s.faults.dups_suppressed >= 6);
                Ok(got)
            } else {
                for i in 0..6 {
                    comm.send_slice::<f64>(0, 9, &[i as f64])?;
                }
                Ok(vec![])
            }
        })
        .unwrap();
        assert_eq!(results[0], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn reorder_plan_preserves_fifo() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                comm.set_fault_plan(Some(
                    faults::FaultPlan::parse("seed=11;retry_ms=5;reorder:p=0.6,ms=2").unwrap(),
                ));
                let mut got = Vec::new();
                for _ in 0..8 {
                    got.push(comm.recv_vec::<f64>(0, 4)?[0]);
                }
                Ok(got)
            } else {
                for i in 0..8 {
                    comm.send_slice::<f64>(1, 4, &[i as f64])?;
                }
                Ok(vec![])
            }
        })
        .unwrap();
        assert_eq!(
            results[1],
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]
        );
    }

    #[test]
    fn dropped_message_recovers_via_retransmit() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                comm.set_fault_plan(Some(
                    faults::FaultPlan::parse("seed=5;retry_ms=5;drop:p=1,tag=40").unwrap(),
                ));
                let got = comm.recv_vec::<f64>(0, 40)?;
                let s = comm.stats();
                assert!(s.faults.injected_drops >= 1);
                assert!(s.faults.retransmits >= 1);
                assert!(s.faults.retries >= 1);
                assert_eq!(s.faults.stragglers, 1);
                Ok(got[0])
            } else {
                comm.send_slice::<f64>(1, 40, &[42.5])?;
                Ok(0.0)
            }
        })
        .unwrap();
        assert_eq!(results[1], 42.5);
    }

    #[test]
    fn truncated_payload_recovers_from_pristine_copy() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                comm.set_fault_plan(Some(
                    faults::FaultPlan::parse("seed=9;truncate:p=1,tag=41").unwrap(),
                ));
                let got = comm.recv_vec::<f64>(0, 41)?;
                let s = comm.stats();
                assert!(s.faults.injected_truncations >= 1);
                assert!(s.faults.retransmits >= 1);
                Ok(got)
            } else {
                comm.send_slice::<f64>(1, 41, &[1.5, -2.5, 3.25])?;
                Ok(vec![])
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![1.5, -2.5, 3.25]);
    }

    #[test]
    fn abandoned_request_discards_late_arrival() {
        // Rank 1 times out on a receive from rank 0 (which is stalled at
        // the barrier), abandons it, then rank 0 sends twice: the first
        // message settles the abandoned request's debt and is discarded,
        // the second matches the retried request.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                comm.set_recv_timeout(Some(Duration::from_millis(50)));
                comm.set_retry_timeout(Some(Duration::from_millis(10)));
                let req = comm.irecv::<f64>(0, 77)?;
                assert!(comm.wait(req).is_err());
                comm.barrier();
                let req = comm.irecv::<f64>(0, 77)?;
                let got = comm.wait(req)?;
                assert!(comm.stats().faults.abandoned_swept >= 1);
                Ok(got[0])
            } else {
                comm.barrier();
                comm.send_slice::<f64>(1, 77, &[-1.0])?;
                comm.send_slice::<f64>(1, 77, &[8.0])?;
                Ok(0.0)
            }
        })
        .unwrap();
        assert_eq!(results[1], 8.0);
    }

    #[test]
    fn kill_rule_fires_only_at_its_step() {
        let plan = faults::FaultPlan::parse("kill:rank=1,step=4").unwrap();
        let results = Cluster::run(2, |comm| {
            comm.set_fault_plan(Some(plan.clone()));
            for step in 0..4 {
                comm.fault_step(step)?;
            }
            Ok(comm.fault_step(4).is_err())
        })
        .unwrap();
        assert_eq!(results, vec![false, true]);
    }
}
