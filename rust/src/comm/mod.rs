//! Message passing for distributed tensor algebra, layered around a
//! pluggable [`Transport`] seam.
//!
//! The paper's claim that the framework "is independent of communication
//! back-end" (§3) is embodied here as an architecture in two halves:
//!
//! * **The engine** (`engine.rs`, exporting [`Comm`]/[`Cluster`]): MPI-style
//!   nonblocking point-to-point requests (`isend_*`/`irecv`/`wait*`/`test`)
//!   with nonovertaking tag matching, the ARQ layer (per-stream wire
//!   sequence numbers, resequencing, duplicate suppression, retransmit
//!   recovery), the registered [buffer pool](PooledBody) with its
//!   receiver-returns-to-sender cycle, fault injection ([`faults`]), and
//!   plan capture ([`plan`]). All of it is written against the
//!   [`Transport`] trait and nothing else.
//!
//! * **The backends**: [`ChannelTransport`] (in-process `mpsc` mesh, the
//!   default and the test substrate) and [`SocketTransport`] (TCP or
//!   Unix-domain sockets, so a [`Cluster`] spans OS processes via
//!   [`Cluster::connect_from_env`]).
//!
//! # The `Transport` contract
//!
//! A backend moves [`Message`]s — `(src, tag, seq, body)` — between ranks
//! and must guarantee exactly three things; everything else (matching,
//! ordering across tags, reliability, flow recovery) belongs to the
//! engine above:
//!
//! 1. **FIFO per pair.** Messages from rank *a* to rank *b* arrive in the
//!    order they were [`send`](Transport::send)ed. No ordering is implied
//!    across different source ranks. The engine's ARQ resequencer assumes
//!    per-pair FIFO as its baseline and repairs everything injected
//!    *above* the transport (delays, duplicates, drops from a fault
//!    plan) — a backend that also reorders internally would need its own
//!    sequencing below the seam, like TCP already provides.
//!
//! 2. **Staging ownership.** A serializing backend (sockets) encodes the
//!    body into wire bytes *inside* [`send`](Transport::send) and then
//!    drops the body — so a pooled send buffer returns to its sender's
//!    pool the moment the bytes are staged, matching the engine's
//!    wire-format staging semantics. A pass-through backend (channels)
//!    must leave the body untouched end to end, which is what preserves
//!    the zero-copy `Arc` payload path and the pool's
//!    receiver-returns-to-sender cycle.
//!
//! 3. **Delivery-seam transparency.** Arrivals are handed to the engine
//!    raw, exactly once each, in arrival order. The fault injector sits
//!    at the engine's delivery seam — *after* the transport — so a
//!    seeded fault plan perturbs a socket backend exactly as it perturbs
//!    the channel backend, which is what makes the chaos suites a
//!    conformance harness for new backends.
//!
//! Backend selection is ambient: [`default_transport`] consults a
//! thread-local [`TransportGuard`] override, then the `PALLAS_TRANSPORT`
//! environment variable, then falls back to channels. [`Cluster::run`]
//! dispatches on it, so any existing test or training loop can be
//! re-pointed at sockets without a signature change.
//!
//! # On-the-wire format
//!
//! Socket backends frame every message as a 36-byte header (magic,
//! version, kind, dtype tag, src, tag, seq, payload length) followed by
//! the payload in the same length-checked little-endian encoding the
//! engine's `set_wire_format` bench knob exercises in-process. Version
//! or framing violations surface as [`Error::Protocol`] — see
//! [`transport`] for the codec and its tests.
//!
//! [`Error::Protocol`]: crate::error::Error::Protocol

pub mod faults;
pub mod plan;
pub mod transport;

mod channel;
mod engine;
mod socket;

pub use channel::ChannelTransport;
pub use engine::{
    configured_recv_timeout, Cluster, Comm, CommGroup, CommPoolStats, CommStats, Payload,
    PooledBody, RecvRequest, SendRequest, COMM_POOL_CAP_ENV, DEFAULT_COMM_POOL_CAP_BYTES,
    MAX_RETRANSMITS_ENV, RECV_TIMEOUT_ENV, RETRY_TIMEOUT_ENV,
};
pub use socket::SocketTransport;
pub use transport::{
    default_transport, Arrival, Message, Transport, TransportGuard, TransportKind,
};
