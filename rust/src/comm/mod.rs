//! Message-passing substrate (the "MPI" of this reproduction).
//!
//! The paper's framework "is independent of communication back-end" (§3);
//! DistDL used MPI via mpi4py. Here the back-end is an in-process SPMD
//! cluster: [`Cluster::run`] spawns one OS thread per world rank and hands
//! each a [`Comm`] endpoint supporting tagged point-to-point send/receive —
//! the paper's primitive "from which all others can be derived". All
//! collectives in [`crate::primitives`] are built strictly on top of
//! send/recv, exactly as the linear-algebraic derivations compose
//! everything from the send-receive copy operator.
//!
//! Semantics match MPI where it matters:
//! * messages between a (source, destination) pair are FIFO;
//! * receives match on `(source, tag)`; non-matching messages are parked in
//!   a local mailbox until a matching receive is posted;
//! * [`Comm::barrier`] is a full-world barrier;
//! * payloads are opaque byte buffers; [`Comm::send_slice`]/[`Comm::recv_vec`]
//!   add a typed length-checked layer used by all primitives.

use crate::error::{Error, Result};
use crate::tensor::Scalar;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// Default receive timeout — generous, but converts a deadlock (the classic
/// distributed-programming failure mode) into a test failure instead of a
/// hang.
const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// A tagged message in flight.
#[derive(Debug)]
struct Message {
    src: usize,
    tag: u64,
    payload: Vec<u8>,
}

/// Per-rank traffic counters (used by benches and the coordinator's metric
/// dump).
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Messages sent by this rank.
    pub messages_sent: usize,
    /// Payload bytes sent by this rank.
    pub bytes_sent: usize,
    /// Messages received.
    pub messages_received: usize,
    /// Payload bytes received.
    pub bytes_received: usize,
}

/// One rank's endpoint into the cluster.
pub struct Comm {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    /// Messages that arrived before a matching receive was posted.
    parked: HashMap<(usize, u64), std::collections::VecDeque<Vec<u8>>>,
    barrier: Arc<Barrier>,
    stats: CommStats,
}

impl Comm {
    /// This endpoint's world rank.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> CommStats {
        self.stats
    }

    /// Send raw bytes to `dst` with `tag`. Never blocks (channels are
    /// unbounded; backpressure is not modelled — the paper's experiments
    /// are synchronous SPMD).
    pub fn send_bytes(&mut self, dst: usize, tag: u64, payload: Vec<u8>) -> Result<()> {
        if dst >= self.size {
            return Err(Error::Comm(format!(
                "send to rank {dst} out of range (world {})",
                self.size
            )));
        }
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += payload.len();
        self.senders[dst]
            .send(Message {
                src: self.rank,
                tag,
                payload,
            })
            .map_err(|_| Error::Comm(format!("rank {dst} disconnected")))
    }

    /// Blocking receive of the next message from `src` with `tag`.
    pub fn recv_bytes(&mut self, src: usize, tag: u64) -> Result<Vec<u8>> {
        // Check the parked mailbox first.
        if let Some(q) = self.parked.get_mut(&(src, tag)) {
            if let Some(payload) = q.pop_front() {
                self.stats.messages_received += 1;
                self.stats.bytes_received += payload.len();
                return Ok(payload);
            }
        }
        loop {
            let msg = self.inbox.recv_timeout(RECV_TIMEOUT).map_err(|_| {
                Error::Comm(format!(
                    "rank {} timed out waiting for (src={src}, tag={tag})",
                    self.rank
                ))
            })?;
            if msg.src == src && msg.tag == tag {
                self.stats.messages_received += 1;
                self.stats.bytes_received += msg.payload.len();
                return Ok(msg.payload);
            }
            self.parked
                .entry((msg.src, msg.tag))
                .or_default()
                .push_back(msg.payload);
        }
    }

    /// Send a typed slice (wire format: little-endian elements, with an
    /// 8-byte element-count header for integrity checking).
    pub fn send_slice<T: Scalar>(&mut self, dst: usize, tag: u64, data: &[T]) -> Result<()> {
        let mut buf = Vec::with_capacity(8 + data.len() * T::WIRE_SIZE);
        buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        T::write_bytes(data, &mut buf);
        self.send_bytes(dst, tag, buf)
    }

    /// Receive a typed vector; errors if the sender's length header
    /// disagrees with the payload.
    pub fn recv_vec<T: Scalar>(&mut self, src: usize, tag: u64) -> Result<Vec<T>> {
        let buf = self.recv_bytes(src, tag)?;
        if buf.len() < 8 {
            return Err(Error::Comm("truncated message header".into()));
        }
        let n = u64::from_le_bytes(buf[..8].try_into().unwrap()) as usize;
        let body = &buf[8..];
        if body.len() != n * T::WIRE_SIZE {
            return Err(Error::Comm(format!(
                "message length {} != {} x {} elements",
                body.len(),
                n,
                T::WIRE_SIZE
            )));
        }
        Ok(T::read_bytes(body))
    }

    /// Exchange slices with a peer (send then receive; safe because sends
    /// never block). The building block of the halo exchange operator C_E.
    pub fn sendrecv<T: Scalar>(
        &mut self,
        peer: usize,
        send_tag: u64,
        recv_tag: u64,
        data: &[T],
    ) -> Result<Vec<T>> {
        self.send_slice(peer, send_tag, data)?;
        self.recv_vec(peer, recv_tag)
    }

    /// Full-world barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// An SPMD cluster of in-process workers.
pub struct Cluster;

impl Cluster {
    /// Run `f` on `world` ranks concurrently and collect per-rank results
    /// in rank order.
    ///
    /// `f` may borrow from the caller (scoped threads). Worker panics are
    /// converted into `Error::Comm` naming the rank.
    pub fn run<R, F>(world: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&mut Comm) -> Result<R> + Send + Sync,
    {
        if world == 0 {
            return Err(Error::Comm("world size must be >= 1".into()));
        }
        let mut senders = Vec::with_capacity(world);
        let mut inboxes = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(rx);
        }
        let barrier = Arc::new(Barrier::new(world));
        let mut comms: Vec<Comm> = inboxes
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| Comm {
                rank,
                size: world,
                senders: senders.clone(),
                inbox,
                parked: HashMap::new(),
                barrier: barrier.clone(),
                stats: CommStats::default(),
            })
            .collect();
        // Drop the original senders so disconnects propagate when workers
        // finish.
        drop(senders);

        let f = &f;
        let results: Vec<Result<R>> = std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .iter_mut()
                .map(|comm| scope.spawn(move || f(comm)))
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| match h.join() {
                    Ok(r) => r,
                    Err(p) => {
                        let msg = p
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "worker panicked".into());
                        Err(Error::Comm(format!("rank {rank} panicked: {msg}")))
                    }
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Like [`Cluster::run`], returning per-rank [`CommStats`] alongside
    /// the results.
    pub fn run_with_stats<R, F>(world: usize, f: F) -> Result<Vec<(R, CommStats)>>
    where
        R: Send,
        F: Fn(&mut Comm) -> Result<R> + Send + Sync,
    {
        Cluster::run(world, |comm| {
            let r = f(comm)?;
            Ok((r, comm.stats()))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = Cluster::run(4, |comm| {
            let next = (comm.rank() + 1) % comm.size();
            let prev = (comm.rank() + comm.size() - 1) % comm.size();
            comm.send_slice::<f64>(next, 1, &[comm.rank() as f64])?;
            let got = comm.recv_vec::<f64>(prev, 1)?;
            Ok(got[0])
        })
        .unwrap();
        assert_eq!(results, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn single_rank_world() {
        let r = Cluster::run(1, |comm| {
            assert_eq!(comm.size(), 1);
            Ok(comm.rank())
        })
        .unwrap();
        assert_eq!(r, vec![0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        // Rank 0 sends tag 2 then tag 1; rank 1 receives tag 1 first.
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 2, &[20.0])?;
                comm.send_slice::<f64>(1, 1, &[10.0])?;
                Ok(0.0)
            } else {
                let a = comm.recv_vec::<f64>(0, 1)?[0];
                let b = comm.recv_vec::<f64>(0, 2)?[0];
                Ok(a * 1000.0 + b)
            }
        })
        .unwrap();
        assert_eq!(results[1], 10020.0);
    }

    #[test]
    fn fifo_within_same_tag() {
        let results = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                for i in 0..5 {
                    comm.send_slice::<f64>(1, 7, &[i as f64])?;
                }
                Ok(vec![])
            } else {
                let mut got = Vec::new();
                for _ in 0..5 {
                    got.push(comm.recv_vec::<f64>(0, 7)?[0]);
                }
                Ok(got)
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sendrecv_exchange() {
        let results = Cluster::run(2, |comm| {
            let peer = 1 - comm.rank();
            let mine = [comm.rank() as f32 + 1.0];
            let theirs = comm.sendrecv(peer, 9, 9, &mine)?;
            Ok(theirs[0])
        })
        .unwrap();
        assert_eq!(results, vec![2.0, 1.0]);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        Cluster::run(4, |comm| {
            counter.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            // after the barrier every rank must see all increments
            assert_eq!(counter.load(Ordering::SeqCst), 4);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn worker_panic_is_reported() {
        let err = Cluster::run(2, |comm| {
            if comm.rank() == 1 {
                panic!("boom");
            }
            Ok(())
        })
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("rank 1") && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn send_out_of_range_errors() {
        let res = Cluster::run(1, |comm| comm.send_slice::<f32>(5, 0, &[1.0]));
        assert!(res.is_err());
    }

    #[test]
    fn stats_count_traffic() {
        let out = Cluster::run_with_stats(2, |comm| {
            let peer = 1 - comm.rank();
            comm.send_slice::<f64>(peer, 3, &[1.0, 2.0, 3.0])?;
            let _ = comm.recv_vec::<f64>(peer, 3)?;
            Ok(())
        })
        .unwrap();
        for (_, s) in out {
            assert_eq!(s.messages_sent, 1);
            assert_eq!(s.messages_received, 1);
            assert_eq!(s.bytes_sent, 8 + 24);
        }
    }

    #[test]
    fn typed_wire_integrity() {
        // Sending f64 but receiving f32 must fail the length check.
        let res = Cluster::run(2, |comm| {
            if comm.rank() == 0 {
                comm.send_slice::<f64>(1, 4, &[1.0, 2.0, 3.0])?;
                Ok(())
            } else {
                match comm.recv_vec::<f32>(0, 4) {
                    Err(Error::Comm(_)) => Ok(()),
                    other => panic!("expected comm error, got {other:?}"),
                }
            }
        });
        assert!(res.is_ok());
    }
}
