//! # DistDL-RS
//!
//! A Rust + JAX + Pallas reproduction of *"A Linear Algebraic Approach to
//! Model Parallelism in Deep Learning"* (Hewett & Grady, 2020) — the DistDL
//! paper.
//!
//! The paper's thesis: the data-movement operations required for distributed
//! (model-parallel) deep learning — broadcast, sum-reduce, scatter/gather,
//! all-to-all, and the generalized (unbalanced) halo exchange — are **linear
//! operators**. By fixing the spaces they act on and the (Euclidean) inner
//! product, their adjoints can be derived *by hand*, so gradient-based
//! training does not require an automatic-differentiation tool that
//! understands message passing. Distributed layers are then built by
//! composing these primitives with ordinary sequential layer kernels.
//!
//! This crate implements the whole stack:
//!
//! * [`tensor`] — dense row-major tensors (`f32`/`f64`) with the region-copy
//!   machinery every primitive is built on (one shared region-offset
//!   iterator behind every copy/add/extract/fill form) and **pluggable
//!   storage**: a tensor is backed by an owned buffer or wraps a
//!   registered comm-pool message buffer directly (zero-copy receive
//!   sides, copy-on-write on mutation, drop-returns-to-sender);
//!   `tensor::ops::matmul` routes through the shared GEMM core below.
//! * [`partition`] — cartesian worker grids and load-balanced tensor
//!   decompositions (§3–4 of the paper).
//! * [`memory`] — the linear-algebraic memory model of §2 / Appendix A:
//!   allocate, clear, add, copy, move, and their adjoints — plus the
//!   [`memory::Scratch`] arena that applies the same algebra to the hot
//!   path: each coordinator rank thread owns a buffer pool whose `take`
//!   replaces a deallocate/re-allocate round trip with the clear operator
//!   `K_b`, so im2col columns, GEMM pack panels, halo staging, activation
//!   stashes, and halo-adjoint message pieces are reused across
//!   micro-batches (counters prove steady-state steps allocate nothing);
//!   a `PALLAS_SCRATCH_CAP_BYTES` cap (default 64 MiB per arena, `0` =
//!   uncapped) turns oversized `give`s into real deallocations (counted
//!   as evictions) so long-lived ranks don't hoard peak-shaped buffers.
//! * [`comm`] — an MPI-like message-passing substrate (threads + channels)
//!   built as a **nonblocking request engine**: `isend`/`irecv` post
//!   operations and return requests completed by
//!   `wait`/`wait_all`/`wait_any`/`test` (`wait_any` drains arrivals in
//!   arrival order — the gather and all-to-all assemblies run on it),
//!   payloads travel a typed zero-copy `Arc` path (the length-checked wire
//!   format remains as fallback), and the blocking API survives as thin
//!   wrappers. Each endpoint owns a **registered buffer pool**
//!   (`PALLAS_COMM_POOL_CAP_BYTES` capped): message payloads are staged in
//!   the sender's pool and the receiver's completion returns them there,
//!   so one-way flows — the broadcast/sum-reduce trees, scatter/gather,
//!   forward-only halo circulation — recycle instead of allocating. The
//!   paper's model is explicitly back-end independent. The engine carries
//!   a **failure model**: per-`(sender, tag)` wire sequence numbers with
//!   duplicate suppression and out-of-order resequencing, recoverable
//!   timeouts (retry threshold with exponential backoff and bounded
//!   retransmits below a fatal deadline), abandoned requests swept rather
//!   than leaked, and a seeded deterministic fault-injection layer
//!   ([`comm::faults`], `PALLAS_FAULT_PLAN`) that delays, drops,
//!   duplicates, reorders, truncates, or kills on schedule.
//! * [`checkpoint`] — per-rank binary snapshots of parameters, Adam
//!   state, and the step index; kill-at-step-k + resume reproduces the
//!   uninterrupted run bitwise.
//! * [`primitives`] — §3: send/recv, scatter/gather, broadcast, sum-reduce,
//!   all-reduce, generalized all-to-all (repartition), and the generalized
//!   unbalanced halo exchange — each a [`adjoint::LinearOp`] with a
//!   hand-derived adjoint, all scheduled post-all-then-complete on the
//!   request engine; the halo exchange splits into `start`/`finish` in
//!   **both directions** — the distributed conv computes its
//!   halo-independent interior while forward halo messages are in flight
//!   (on slabs its trim/pad shim extracts straight from the exchange
//!   buffer), and its backward runs the δw/δb GEMMs and the parameter
//!   sum-reduce while the δx halo-adjoint messages move
//!   (`adjoint_start`/`adjoint_finish`).
//! * [`halo`] — Appendix B halo geometry: per-worker left/right halo widths
//!   and "unused input" regions for arbitrary kernel size/stride/dilation/
//!   padding.
//! * [`adjoint`] — the coherence test of Eq. (13).
//! * [`analysis`] — the **static communication-plan verifier**: because
//!   every data-movement op is a linear operator with a known adjoint, a
//!   run's full cross-rank message schedule is a finite object that can
//!   be captured *without executing any kernel math* (`comm::plan`
//!   capture mode, driven through each primitive's `DistLinearOp`
//!   interface on zero-filled shards) and checked pre-flight: endpoint
//!   matching, tag-space collisions, deadlock freedom (wait-for-graph
//!   replay), adjoint duality (backward plan = forward plan transposed —
//!   the static shadow of Eq. 13), and staging-pool balance. Surfaced as
//!   the `check` CLI subcommand and the `preflight_check` train option.
//! * [`autograd`] — a tape-based reverse-mode engine standing in for
//!   torch.autograd; primitives register their adjoints as backward ops.
//! * [`nn`] — §4 distributed layers (conv, pool, affine, transpose,
//!   pointwise) over both native Rust kernels and AOT-compiled XLA
//!   executables. The native sequential layer functions share one compute
//!   core: the cache-blocked GEMM in `nn::native::gemm`, running on a
//!   **persistent per-rank worker pool** (parked std threads, sized by
//!   `available_parallelism` with a `PALLAS_GEMM_THREADS` override) with
//!   shared packed-B panels and a SIMD-width-aware microkernel dispatch
//!   (4×16 `f32` / 4×8 `f64` register tiles) — bitwise reproducible
//!   across worker counts. The affine kernel reaches it directly, the
//!   convolution kernels through im2col/col2im; the conv VJP splits into
//!   δx and δw/δb halves so the layer's backward overlaps them with the
//!   adjoint exchange. The original scalar loops and the scoped-spawn
//!   GEMM scheduler survive as `*_naive`/`gemm_scoped` references for
//!   parity tests and speedup benches.
//! * [`runtime`] — PJRT loading/execution of `artifacts/*.hlo.txt` produced
//!   by the JAX/Pallas compile path (`python/compile`); gated behind the
//!   `pjrt` cargo feature (off by default — the crate builds with zero
//!   external dependencies), with a native-fallback stub otherwise.
//! * [`models`], [`data`], [`optim`], [`coordinator`] — the distributed
//!   LeNet-5 of §5 / Appendix C, a synthetic MNIST, optimizers, and the SPMD
//!   training orchestrator.
//!
//! The same algebra extends to **hybrid data×model parallelism**: the
//! world factors as `replicas × model-grid`
//! (`partition::HybridTopology`, per-axis communicators split out of the
//! endpoint map), the bandwidth-optimal **ring all-reduce** is derived
//! from send/receive like every other primitive
//! (`primitives::RingAllReduce`, self-adjoint up to its real `1/R`
//! averaging scale, Eq. 13-coherent), and the `optim::dp` engine buckets
//! gradient shards and rides their ring averaging *inside* the backward
//! overlap window — replicas' optimizer states stay bit-identical without
//! any optimizer-state synchronisation.
//!
//! The third axis is **micro-batch pipeline parallelism** (`replicas ×
//! stages × model-grid`): the layer sequence is cut into contiguous
//! stages, stage boundaries are `primitives::PipeMove` send-receives
//! (forward activation out, Eq. 12 adjoint cotangent home, Eq.
//! 13-coherent), and the `optim::pp` engine streams `m` micro-batches
//! through the stages on the 1F1B schedule — boundary messages recycle
//! through the registered pool, gradients accumulate across micro-batches
//! in micro order (bitwise equal to the serialized lockstep reference and
//! to the unstaged sequential tape), and the DP ring hook fires in the
//! last micro-batch's backward so all three axes compose.
//! * [`util`], [`testing`], [`cli`] — hand-rolled substrates (JSON, PRNG,
//!   property-test and bench harnesses, argument parsing); the crates this
//!   build cannot take as dependencies.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request/training path is pure Rust + PJRT.

// Numeric-kernel idiom: explicit index loops mirror the paper's subscript
// algebra and keep packed-buffer offset arithmetic auditable; the GEMM
// entry points legitimately take the full (m, n, k, operands, layout)
// parameter set. `unknown_lints` keeps older clippy versions from choking
// on newer lint names.
#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_div_ceil)]
// The only unsafe code in the crate is the GEMM core's scoped
// raw-pointer tiling (`nn::native::gemm`, audited with SAFETY comments
// and module-scoped `#[allow(unsafe_code)]`); everything else is denied.
#![deny(unsafe_code)]

pub mod adjoint;
pub mod analysis;
pub mod autograd;
pub mod checkpoint;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod halo;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod nn;
pub mod optim;
pub mod partition;
pub mod primitives;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
pub use tensor::{Scalar, Tensor};
