//! Checkpoint/restore for the training loop — the recovery half of the
//! failure model (the other half, in-flight message recovery, lives in
//! [`crate::comm::faults`]).
//!
//! A checkpoint is **per rank**: rank `r` of a `W`-rank world serializes
//! its own parameter shards, Adam state (step clock `t` plus both moment
//! vectors), the seed, and the step index into
//! `dir/step_NNNNNN/rank_R.ckpt`. Together the `W` files are a complete,
//! bitwise snapshot of the run: every other piece of training state is a
//! pure function of `(config, seed, step)` — synthetic data is
//! regenerated from the seed, the batch schedule is indexed by absolute
//! step, and layer RNG initialisation is overwritten wholesale by the
//! restored parameters — so a resumed run replays the uninterrupted run
//! **bit for bit** (asserted in `tests/fault_tolerance.rs`).
//!
//! The format is a little-endian binary layout written through
//! [`crate::tensor::Scalar::write_bytes`] — the comm wire codec — rather
//! than JSON, because JSON round-trips floats through decimal and a
//! checkpoint that perturbs the last mantissa bit is not a checkpoint.
//! Files are written to a `.tmp` sibling and atomically renamed, so a
//! rank killed mid-write can never leave a torn checkpoint behind.

use crate::autograd::NetworkState;
use crate::error::{Error, Result};
use crate::optim::Adam;
use crate::tensor::{Scalar, Tensor};
use std::path::{Path, PathBuf};

/// Magic header identifying the checkpoint format (version-stamped).
const MAGIC: &[u8; 8] = b"PLCKPT01";

/// Directory holding one step's per-rank checkpoint files.
pub fn step_dir(dir: &str, step: u64) -> PathBuf {
    Path::new(dir).join(format!("step_{step:06}"))
}

/// Path of one rank's checkpoint file within a step directory.
pub fn rank_file(step_dir: &Path, rank: usize) -> PathBuf {
    step_dir.join(format!("rank_{rank}.ckpt"))
}

/// One rank's complete training state at a step boundary.
#[derive(Debug, Clone)]
pub struct Checkpoint<T: Scalar> {
    /// World size the run used (validated on resume).
    pub world: usize,
    /// Rank this snapshot belongs to.
    pub rank: usize,
    /// The run's seed (validated on resume — restored parameters only
    /// reproduce the uninterrupted run if the data stream matches).
    pub seed: u64,
    /// Completed steps; the resumed run continues at this step index.
    pub step: u64,
    /// Parameter shards, per layer (empty inner vecs for layers this rank
    /// holds no parameters of — the structure mirrors
    /// [`NetworkState::states`]).
    pub params: Vec<Vec<Tensor<T>>>,
    /// Adam step clock `t`.
    pub opt_t: u64,
    /// Adam first moments, in [`NetworkState::params_and_grads`] order
    /// (empty if the optimizer had not stepped yet).
    pub opt_m: Vec<Tensor<T>>,
    /// Adam second moments.
    pub opt_v: Vec<Tensor<T>>,
}

impl<T: Scalar> Checkpoint<T> {
    /// Snapshot a rank's live training state.
    pub fn capture(
        world: usize,
        rank: usize,
        seed: u64,
        step: u64,
        state: &NetworkState<T>,
        opt: &Adam<T>,
    ) -> Self {
        let params = state.states.iter().map(|s| s.params.clone()).collect();
        let (m, v) = opt.moments();
        Checkpoint {
            world,
            rank,
            seed,
            step,
            params,
            opt_t: opt.t(),
            opt_m: m.to_vec(),
            opt_v: v.to_vec(),
        }
    }

    /// Serialize into the binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        write_u64(&mut buf, T::WIRE_SIZE as u64);
        write_u64(&mut buf, self.world as u64);
        write_u64(&mut buf, self.rank as u64);
        write_u64(&mut buf, self.seed);
        write_u64(&mut buf, self.step);
        write_u64(&mut buf, self.params.len() as u64);
        for layer in &self.params {
            write_u64(&mut buf, layer.len() as u64);
            for t in layer {
                write_tensor(&mut buf, t);
            }
        }
        write_u64(&mut buf, self.opt_t);
        write_u64(&mut buf, self.opt_m.len() as u64);
        for t in &self.opt_m {
            write_tensor(&mut buf, t);
        }
        write_u64(&mut buf, self.opt_v.len() as u64);
        for t in &self.opt_v {
            write_tensor(&mut buf, t);
        }
        buf
    }

    /// Parse the binary format.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            return Err(Error::Config("not a checkpoint file (bad magic)".into()));
        }
        let wire = r.u64()? as usize;
        if wire != T::WIRE_SIZE {
            return Err(Error::Config(format!(
                "checkpoint element size {wire} != expected {}",
                T::WIRE_SIZE
            )));
        }
        let world = r.u64()? as usize;
        let rank = r.u64()? as usize;
        let seed = r.u64()?;
        let step = r.u64()?;
        let layers = r.u64()? as usize;
        let mut params = Vec::with_capacity(layers);
        for _ in 0..layers {
            let n = r.u64()? as usize;
            let mut layer = Vec::with_capacity(n);
            for _ in 0..n {
                layer.push(r.tensor::<T>()?);
            }
            params.push(layer);
        }
        let opt_t = r.u64()?;
        let nm = r.u64()? as usize;
        let mut opt_m = Vec::with_capacity(nm);
        for _ in 0..nm {
            opt_m.push(r.tensor::<T>()?);
        }
        let nv = r.u64()? as usize;
        let mut opt_v = Vec::with_capacity(nv);
        for _ in 0..nv {
            opt_v.push(r.tensor::<T>()?);
        }
        if r.pos != buf.len() {
            return Err(Error::Config(format!(
                "checkpoint has {} trailing bytes",
                buf.len() - r.pos
            )));
        }
        Ok(Checkpoint {
            world,
            rank,
            seed,
            step,
            params,
            opt_t,
            opt_m,
            opt_v,
        })
    }

    /// Write this snapshot under `dir/step_NNNNNN/rank_R.ckpt`,
    /// atomically (tmp + rename), creating directories as needed.
    pub fn save(&self, dir: &str) -> Result<PathBuf> {
        let sdir = step_dir(dir, self.step);
        std::fs::create_dir_all(&sdir)?;
        let path = rank_file(&sdir, self.rank);
        let tmp = path.with_extension("ckpt.tmp");
        std::fs::write(&tmp, self.to_bytes())?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// Load one rank's snapshot from a step directory.
    pub fn load(step_dir: &Path, rank: usize) -> Result<Self> {
        let path = rank_file(step_dir, rank);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Config(format!("reading checkpoint {path:?}: {e}")))?;
        Self::from_bytes(&bytes)
    }

    /// Restore the live training state from this snapshot: overwrite
    /// every parameter shard and the optimizer's clock and moments.
    /// Shapes are validated against the freshly initialised state, so a
    /// checkpoint from a different topology or model fails loudly.
    pub fn apply(&self, state: &mut NetworkState<T>, opt: &mut Adam<T>) -> Result<()> {
        if self.params.len() != state.states.len() {
            return Err(Error::Config(format!(
                "checkpoint has {} layers, network has {}",
                self.params.len(),
                state.states.len()
            )));
        }
        for (i, (saved, live)) in self.params.iter().zip(state.states.iter_mut()).enumerate() {
            if saved.len() != live.params.len() {
                return Err(Error::Config(format!(
                    "layer {i}: checkpoint has {} params, network has {}",
                    saved.len(),
                    live.params.len()
                )));
            }
            for (s, l) in saved.iter().zip(live.params.iter()) {
                if s.shape() != l.shape() {
                    return Err(Error::Config(format!(
                        "layer {i}: checkpoint param shape {:?} != network {:?}",
                        s.shape(),
                        l.shape()
                    )));
                }
            }
            live.params = saved.clone();
        }
        opt.restore(self.opt_t, self.opt_m.clone(), self.opt_v.clone())
    }
}

fn write_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn write_tensor<T: Scalar>(buf: &mut Vec<u8>, t: &Tensor<T>) {
    write_u64(buf, t.shape().len() as u64);
    for &d in t.shape() {
        write_u64(buf, d as u64);
    }
    T::write_bytes(t.data(), buf);
}

/// Bounds-checked cursor over a checkpoint byte buffer.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            return Err(Error::Config("truncated checkpoint".into()));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn tensor<T: Scalar>(&mut self) -> Result<Tensor<T>> {
        let ndim = self.u64()? as usize;
        if ndim > 8 {
            return Err(Error::Config(format!(
                "checkpoint tensor rank {ndim} implausible (corrupt file?)"
            )));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u64()? as usize);
        }
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .and_then(|n| n.checked_mul(T::WIRE_SIZE))
            .ok_or_else(|| Error::Config("checkpoint tensor shape overflows".into()))?;
        let bytes = self.take(numel)?;
        Tensor::from_vec(&shape, T::read_bytes(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::LayerState;

    fn sample_state() -> NetworkState<f32> {
        let l0 = LayerState::with_params(vec![
            Tensor::from_vec(&[2, 3], vec![1.5, -2.25, 3.0, 0.0, -0.5, 8.125]).unwrap(),
            Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]).unwrap(),
        ]);
        let l1 = LayerState::with_params(vec![]);
        let l2 = LayerState::with_params(vec![Tensor::scalar(7.0)]);
        NetworkState {
            states: vec![l0, l1, l2],
        }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let mut state = sample_state();
        let mut opt = Adam::new(1e-3);
        // Step once so the moments are non-trivial.
        state.states[0].grads[0] = Tensor::from_vec(
            &[2, 3],
            vec![0.5, -0.25, 0.125, 1.0, -1.0, 2.0],
        )
        .unwrap();
        opt.step(&mut state).unwrap();
        let ck = Checkpoint::capture(4, 2, 42, 17, &state, &opt);
        let back = Checkpoint::<f32>::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.world, 4);
        assert_eq!(back.rank, 2);
        assert_eq!(back.seed, 42);
        assert_eq!(back.step, 17);
        assert_eq!(back.opt_t, 1);
        for (a, b) in ck.params.iter().flatten().zip(back.params.iter().flatten()) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data());
        }
        for (a, b) in ck.opt_m.iter().zip(back.opt_m.iter()) {
            assert_eq!(a.data(), b.data());
        }
        for (a, b) in ck.opt_v.iter().zip(back.opt_v.iter()) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn apply_restores_params_and_optimizer() {
        let mut state = sample_state();
        let mut opt = Adam::new(1e-3);
        state.states[0].grads[0] =
            Tensor::from_vec(&[2, 3], vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0]).unwrap();
        opt.step(&mut state).unwrap();
        let ck = Checkpoint::capture(1, 0, 7, 3, &state, &opt);

        // A fresh state/optimizer restored from the snapshot matches the
        // original bitwise.
        let mut fresh = sample_state();
        let mut fresh_opt = Adam::new(1e-3);
        ck.apply(&mut fresh, &mut fresh_opt).unwrap();
        assert_eq!(fresh_opt.t(), opt.t());
        for (a, b) in state
            .states
            .iter()
            .flat_map(|s| s.params.iter())
            .zip(fresh.states.iter().flat_map(|s| s.params.iter()))
        {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn apply_rejects_shape_mismatch() {
        let state = sample_state();
        let opt = Adam::new(1e-3);
        let ck = Checkpoint::capture(1, 0, 7, 0, &state, &opt);
        let mut other = NetworkState::<f32> {
            states: vec![LayerState::with_params(vec![Tensor::scalar(0.0)])],
        };
        let mut other_opt = Adam::new(1e-3);
        assert!(ck.apply(&mut other, &mut other_opt).is_err());
    }

    #[test]
    fn corrupt_files_are_rejected() {
        let state = sample_state();
        let opt = Adam::new(1e-3);
        let bytes = Checkpoint::capture(1, 0, 7, 0, &state, &opt).to_bytes();
        assert!(Checkpoint::<f32>::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(Checkpoint::<f32>::from_bytes(b"not a checkpoint").is_err());
        // Wrong element width: an f64 reader rejects an f32 checkpoint.
        assert!(Checkpoint::<f64>::from_bytes(&bytes).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(Checkpoint::<f32>::from_bytes(&extra).is_err());
    }

    #[test]
    fn save_and_load_roundtrip_on_disk() {
        let state = sample_state();
        let opt = Adam::new(1e-3);
        let dir = std::env::temp_dir().join(format!("pallas_ckpt_test_{}", std::process::id()));
        let dir_s = dir.to_string_lossy().to_string();
        let ck = Checkpoint::capture(1, 0, 99, 5, &state, &opt);
        let path = ck.save(&dir_s).unwrap();
        assert!(path.ends_with("step_000005/rank_0.ckpt"));
        let back = Checkpoint::<f32>::load(&step_dir(&dir_s, 5), 0).unwrap();
        assert_eq!(back.seed, 99);
        assert_eq!(back.step, 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
