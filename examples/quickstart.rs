//! Quickstart: the paper's framework in five minutes.
//!
//! Builds a 4-worker cluster, demonstrates each parallel primitive with
//! its hand-derived adjoint, verifies Eq. (13) coherence, and runs one
//! distributed LeNet-5 training step.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use distdl::adjoint::{adjoint_residual, DistLinearOp};
use distdl::comm::Cluster;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{Broadcast, HaloExchange, Repartition, SumReduce};
use distdl::tensor::Tensor;
use distdl::error::Result;

fn main() -> Result<()> {
    println!("distdl quickstart — linear-algebraic model parallelism\n");

    // 1. Broadcast: one worker's tensor replicated to four; the adjoint
    //    (Eq. 9) is a sum-reduction.
    let bcast = Broadcast::replicate(0, 4, &[4], 10)?;
    let outs = Cluster::run(4, |comm| {
        let x = (comm.rank() == 0).then(|| Tensor::<f64>::iota(&[4]));
        bcast.forward(comm, x)
    })?;
    println!("broadcast: every rank now holds {:?}", outs[3].as_ref().unwrap().data());

    let reduced = Cluster::run(4, |comm| {
        let y = Some(Tensor::<f64>::filled(&[4], (comm.rank() + 1) as f64));
        bcast.adjoint(comm, y)
    })?;
    println!(
        "adjoint of broadcast = sum-reduce: root got {:?} (1+2+3+4 per slot)",
        reduced[0].as_ref().unwrap().data()
    );

    // 2. Sum-reduce is literally the same operator applied the other way.
    let reduce = SumReduce::to_root(0, 4, &[2], 20)?;
    let r = adjoint_residual::<f64>(4, &reduce, 7)?;
    println!("sum-reduce Eq. (13) residual: {r:.2e}");

    // 3. Repartition (generalized all-to-all): rows -> columns.
    let rows = TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[4, 4])?;
    let cols = TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[4, 4])?;
    let transpose = Repartition::new(rows.clone(), cols, 30)?;
    let shards = Cluster::run(2, |comm| {
        let x = rows
            .region_of(comm.rank())
            .map(|r| Tensor::<f64>::from_fn(&r.shape, |i| ((r.start[0] + i[0]) * 4 + r.start[1] + i[1]) as f64));
        transpose.forward(comm, x)
    })?;
    println!(
        "all-to-all: rank 0 went from rows [4x2... to column shard {:?}",
        shards[0].as_ref().unwrap().shape()
    );

    // 4. The generalized unbalanced halo exchange (Fig. B5 geometry).
    let geom = HaloGeometry::new(&[20], &[6], &[KernelSpec::pool(2, 2)])?;
    let halo = HaloExchange::new(Partition::from_shape(&[6]), geom, 40)?;
    let r = adjoint_residual::<f64>(6, &halo, 11)?;
    println!("unbalanced halo exchange Eq. (13) residual: {r:.2e}");

    // 5. One distributed LeNet-5 training step on 4 workers.
    let cfg = distdl::config::TrainConfig {
        batch: 16,
        steps: 3,
        dataset: 64,
        distributed: true,
        ..Default::default()
    };
    let report = distdl::coordinator::train(&cfg)?;
    println!(
        "\ndistributed LeNet-5 (4 workers): step losses {:?}",
        report
            .log
            .steps
            .iter()
            .map(|s| (s.loss * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!("params per rank: {:?} (Table 1 placement)", report.params_per_rank);
    println!("\nquickstart OK — see examples/distributed_lenet5.rs for the full experiment");
    Ok(())
}
