//! Adjoint-coherence suite (E1): runs the Eq. (13) test for every
//! parallel primitive across worker counts and tensor scales and prints
//! the residual table — the paper's §3 "Implementation" verification.
//!
//! ```bash
//! cargo run --release --example adjoint_suite            # default scales
//! cargo run --release --example adjoint_suite -- 64      # single scale
//! ```

use distdl::error::Result;
use distdl::coordinator::suites::run_adjoint_suite;

fn main() -> Result<()> {
    let scales: Vec<usize> = {
        let args: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if args.is_empty() {
            vec![8, 32, 128]
        } else {
            args
        }
    };
    for n in scales {
        run_adjoint_suite(n)?;
        println!();
    }
    println!("all primitives coherent (Eq. 13) — the paper's correctness criterion holds");
    Ok(())
}
