//! Halo explorer — regenerates the Appendix B halo-geometry figures
//! (E2–E5) as tables, plus a live 2-D forward/adjoint exchange trace
//! (Figs. B6–B9).
//!
//! ```bash
//! cargo run --release --example halo_explorer
//! cargo run --release --example halo_explorer -- 37 4 5 2 1   # n P k s pad
//! ```

use distdl::error::Result;
use distdl::adjoint::DistLinearOp;
use distdl::comm::Cluster;
use distdl::coordinator::suites::print_halo_tables;
use distdl::halo::{dim_halos, format_dim_table, HaloGeometry, KernelSpec};
use distdl::partition::Partition;
use distdl::primitives::HaloExchange;
use distdl::tensor::Tensor;

fn main() -> Result<()> {
    let args: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    if args.len() >= 4 {
        let (n, p, k, s) = (args[0], args[1], args[2], args[3]);
        let pad = args.get(4).copied().unwrap_or(0);
        let spec = KernelSpec {
            size: k,
            stride: s,
            dilation: 1,
            pad_lo: pad,
            pad_hi: pad,
        };
        println!("custom geometry:");
        print!("{}", format_dim_table(n, &spec, &dim_halos(n, p, &spec)?));
        return Ok(());
    }

    // The four Appendix B case studies.
    print_halo_tables();

    // Live 2-D unbalanced exchange (the B6–B9 sequence).
    println!("\nFigs. B6–B9 — live 2-D unbalanced exchange on a 2x2 partition:");
    let geom = HaloGeometry::new(
        &[9, 7],
        &[2, 2],
        &[KernelSpec::plain(4), KernelSpec::plain(3)],
    )?;
    let part = Partition::from_shape(&[2, 2]);
    let op = HaloExchange::new(part.clone(), geom, 100)?;
    let outs = Cluster::run(4, |comm| {
        let coords = part.coords_of(comm.rank()).unwrap();
        let halos = op.halos_at(&coords);
        let mut buf = Tensor::<f64>::filled(&op.buffer_shape(&coords), -1.0);
        for r in 0..halos[0].in_len {
            for c in 0..halos[1].in_len {
                *buf.at_mut(&[halos[0].left_halo + r, halos[1].left_halo + c]) =
                    (comm.rank() + 1) as f64;
            }
        }
        op.forward(comm, Some(buf))
    })?;
    for (rank, out) in outs.iter().enumerate() {
        let out = out.as_ref().unwrap();
        println!("\nworker {rank} buffer after exchange (values = owning worker + 1):");
        for r in 0..out.shape()[0] {
            let row: Vec<String> = (0..out.shape()[1])
                .map(|c| format!("{:>2.0}", out.at(&[r, c])))
                .collect();
            println!("  {}", row.join(" "));
        }
    }
    println!("\n(adjoint direction verified by `cargo test --test halo_figures`)");
    Ok(())
}
