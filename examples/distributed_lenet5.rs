//! E9 — the end-to-end driver: the paper's §5 / Appendix C experiment.
//!
//! Trains the distributed (4-worker) LeNet-5 and the sequential baseline
//! on identical synthetic-MNIST data from identical initial parameters,
//! over multiple trials, and reports the accuracy statistics the paper
//! reports (98.54% vs 98.55% on real MNIST; here the dataset is synthetic
//! — see DESIGN.md §1 — and the claim under test is *equivalence*).
//!
//! ```bash
//! cargo run --release --example distributed_lenet5                 # full run
//! cargo run --release --example distributed_lenet5 -- --steps 60   # quicker
//! cargo run --release --example distributed_lenet5 -- --describe   # Fig. C10 / Table 1
//! cargo run --release --example distributed_lenet5 -- --backend pjrt
//! ```

use distdl::error::Result;
use distdl::cli::Args;
use distdl::config::{Backend, TrainConfig};
use distdl::coordinator::train;
use distdl::models::{lenet5, LeNetConfig, LeNetLayout};
use distdl::nn::NativeKernels;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if args.has_flag("describe") {
        describe()?;
        return Ok(());
    }
    let steps = args.get_usize("steps")?.unwrap_or(300);
    let trials = args.get_usize("trials")?.unwrap_or(3);
    let batch = args.get_usize("batch")?.unwrap_or(64);
    let backend = match args.get("backend") {
        Some(b) => Backend::parse(b)?,
        None => Backend::Native,
    };

    println!(
        "§5 experiment: LeNet-5, batch {batch}, {steps} steps x {trials} trials, Adam lr=1e-3, backend {backend:?}"
    );
    println!("(paper protocol: 50 trials x 10 epochs on MNIST; scaled for this testbed)\n");

    let mut seq_accs = Vec::new();
    let mut dist_accs = Vec::new();
    let mut max_loss_gap = 0.0f64;
    for trial in 0..trials {
        let base = TrainConfig {
            batch,
            steps,
            lr: 1e-3,
            dataset: (steps * batch).min(16_384).max(batch),
            seed: 1000 + trial as u64, // "random initial network parameters" per trial
            backend,
            ..Default::default()
        };
        let mut seq_cfg = base.clone();
        seq_cfg.distributed = false;
        let mut dist_cfg = base;
        dist_cfg.distributed = true;
        let seq = train(&seq_cfg)?;
        let dist = train(&dist_cfg)?;
        let gap = seq
            .log
            .steps
            .iter()
            .zip(dist.log.steps.iter())
            .map(|(a, b)| (a.loss - b.loss).abs())
            .fold(0.0f64, f64::max);
        max_loss_gap = max_loss_gap.max(gap);
        println!(
            "trial {trial}: sequential eval acc {:>6.2}% | distributed eval acc {:>6.2}% | max per-step |Δloss| {gap:.2e}",
            seq.eval_accuracy.unwrap_or(0.0) * 100.0,
            dist.eval_accuracy.unwrap_or(0.0) * 100.0,
        );
        seq_accs.push(seq.eval_accuracy.unwrap_or(0.0));
        dist_accs.push(dist.eval_accuracy.unwrap_or(0.0));
        // loss curve for the first trial (the e2e evidence in EXPERIMENTS.md)
        if trial == 0 {
            println!("  loss curve (distributed): ");
            for rec in dist.log.steps.iter().step_by((steps / 10).max(1)) {
                println!(
                    "    step {:>5}  loss {:>8.4}  acc {:>6.2}%",
                    rec.step,
                    rec.loss,
                    rec.accuracy * 100.0
                );
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\nmean eval accuracy over {trials} trials: sequential {:.2}% | distributed {:.2}%",
        mean(&seq_accs) * 100.0,
        mean(&dist_accs) * 100.0
    );
    println!("max per-step |Δloss| across all trials: {max_loss_gap:.3e}");
    println!(
        "\n=> \"the sequential and distributed networks produce equivalent results\" (§5): {}",
        if (mean(&seq_accs) - mean(&dist_accs)).abs() < 0.01 {
            "REPRODUCED"
        } else {
            "DIVERGED — investigate"
        }
    );
    Ok(())
}

fn describe() -> Result<()> {
    // Fig. 1 / Fig. C10: the global structure, layer by layer.
    println!("Fig. 1 / C10 — distributed LeNet-5 global structure (4 workers):\n");
    let net = lenet5::<f32>(
        &LeNetConfig {
            batch: 256,
            layout: LeNetLayout::FourWorker,
        },
        Arc::new(NativeKernels),
    )?;
    for layer in net.layers() {
        println!("  {:<16}", layer.name());
    }
    println!("\nTable 1 — learnable parameters per worker, per layer:\n");
    println!("{:<10} {:<28} {:<14} {:<24} {:<14}", "Layer", "Worker 0", "Worker 1", "Worker 2", "Worker 3");
    let reports: Vec<_> = (0..4).map(|r| net.placement_report(r)).collect();
    for li in 0..reports[0].len() {
        let lname = &reports[0][li].0;
        let cells: Vec<String> = reports
            .iter()
            .map(|r| {
                let p = &r[li].1;
                if p.is_empty() {
                    "None".into()
                } else {
                    p.iter()
                        .map(|(n, s)| format!("{n}: {s:?}"))
                        .collect::<Vec<_>>()
                        .join("  ")
                }
            })
            .collect();
        if cells.iter().any(|c| c != "None") {
            println!("{:<10} {:<28} {:<14} {:<24} {:<14}", lname, cells[0], cells[1], cells[2], cells[3]);
        }
    }
    Ok(())
}
