"""L1 — the Pallas GEMM kernel.

The compute hot-spot of every layer in the paper's LeNet-5 — the local
convolution (via im2col) and the local affine — is a dense matmul. This
kernel expresses that matmul as a Pallas grid over MXU-aligned tiles:

* the grid is ``(m/bm, n/bn, k/bk)``; each step multiplies one
  ``bm x bk`` LHS tile against one ``bk x bn`` RHS tile and accumulates
  into the ``bm x bn`` output tile — the BlockSpecs express the HBM->VMEM
  schedule a TPU would execute;
* tiles default to 128x128, the MXU systolic-array shape, and shrink to
  the (padded) problem when it is smaller;
* inputs are zero-padded up to tile multiples and the result is sliced
  back, so any shape is accepted.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel is lowered through the Pallas
interpreter into plain HLO (see DESIGN.md §2 "Hardware adaptation"). The
pure-jnp oracle in :mod:`compile.kernels.ref` pins down the numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile edge.
TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One grid step: accumulate a_tile @ b_tile into the output tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(value: int, mult: int) -> int:
    return (value + mult - 1) // mult * mult


def auto_blocks(m: int, k: int, n: int) -> tuple:
    """Pick block shapes adaptively.

    Perf iteration L1-1 (see EXPERIMENTS.md §Perf): fixed 128³ tiles give
    LeNet's skinny GEMMs (e.g. [6,25] @ [25,50176]) grids of ~400 steps;
    under the Pallas interpreter each grid step is a loop iteration, so
    step count dominates wall-clock. We grow each block up to the (padded)
    problem size within a per-tile cap that still respects a TPU VMEM
    budget (tile bytes ≤ ~2.7 MiB ⇒ ~8 MiB live with double-buffered
    inputs, within a 16 MiB core). Grids collapse to a handful of steps
    while MXU alignment (multiples of 128 where the dim allows) is kept.
    """
    bm = min(_ceil_to(max(m, 1), 8), 256)
    bk = min(_ceil_to(max(k, 1), 8), 512)
    # remaining budget for bn: keep bm*bk + bk*bn + bm*bn under ~700k f32
    budget = 700_000
    room = max(budget - bm * bk, bm + bk) // (bm + bk)
    bn = min(_ceil_to(max(n, 1), 128), max(128, room // 128 * 128), 2048)
    return bm, bk, bn


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def pallas_matmul(a, b, *, bm: int = 0, bk: int = 0, bn: int = 0):
    """``a [m, k] @ b [k, n] -> [m, n]`` through the Pallas tile kernel.

    Block sizes default to :func:`auto_blocks`; pass explicit ``bm/bk/bn``
    to pin them (the tests use this to check tiling invariance).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    abm, abk, abn = auto_blocks(m, k, n)
    bm = bm or abm
    bk = bk or abk
    bn = bn or abn
    bm = min(bm, _ceil_to(max(m, 1), 8))
    bk = min(bk, _ceil_to(max(k, 1), 8))
    bn = min(bn, _ceil_to(max(n, 1), 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a_pad = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_pad = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a_pad.astype(jnp.float32), b_pad.astype(jnp.float32))
    return out[:m, :n]


def vmem_footprint_bytes(bm: int = 256, bk: int = 512, bn: int = 2048) -> int:
    """Estimated VMEM bytes live per grid step (f32 tiles, double-buffered
    inputs). Used by the DESIGN.md/EXPERIMENTS.md roofline estimate."""
    tiles = 2 * (bm * bk) + 2 * (bk * bn) + bm * bn
    return tiles * 4
