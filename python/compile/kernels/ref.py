"""Pure-jnp correctness oracles for the L1/L2 kernels.

These define the semantics the Pallas kernel and the L2 layer functions
must match (pytest asserts allclose). The backward oracles are obtained by
`jax.vjp` of the forward oracles — this is exactly the role AD plays in
the paper: the *local* layer functions may use AD freely; only the
*distributed* data movement needs hand-derived adjoints (which live on the
Rust side).
"""

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain jnp matmul oracle."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def affine_ref(x, w, b=None):
    """y = x @ w.T (+ b) with x [B, FI], w [FO, FI], b [FO]."""
    y = jnp.dot(x, w.T)
    if b is not None:
        y = y + b[None, :]
    return y


def conv2d_ref(x, w, b=None, stride=(1, 1)):
    """Valid NCHW/OIHW convolution oracle (lax.conv)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def affine_bwd_ref(x, w, dy, with_bias=True):
    """(dx, dw, db) oracle via jax.vjp of the forward oracle."""
    if with_bias:
        b = jnp.zeros((w.shape[0],), dtype=x.dtype)
        _, vjp = jax.vjp(lambda x_, w_, b_: affine_ref(x_, w_, b_), x, w, b)
        return vjp(dy)
    _, vjp = jax.vjp(lambda x_, w_: affine_ref(x_, w_), x, w)
    dx, dw = vjp(dy)
    return dx, dw, jnp.sum(dy, axis=0)


def conv2d_bwd_ref(x, w, dy, stride=(1, 1)):
    """(dx, dw, db) oracle via jax.vjp of the forward oracle."""
    b = jnp.zeros((w.shape[0],), dtype=x.dtype)
    _, vjp = jax.vjp(lambda x_, w_, b_: conv2d_ref(x_, w_, b_, stride), x, w, b)
    return vjp(dy)
