"""L2 — the JAX layer functions lowered to the Rust runtime.

These are the *sequential* local kernels of the paper's §4 distributed
layers (the parallel structure lives entirely in Rust): convolution
forward/backward and affine forward/backward, each built on the L1 Pallas
GEMM (:mod:`compile.kernels.matmul`). The backward functions are written
explicitly — as the paper emphasises, the data-movement adjoints are
hand-derived on the Rust side, and here the local VJPs are plain linear
algebra (matmuls again), so no AD is traced through the Pallas call.

Every function here is shape-specialised and lowered once by
:mod:`compile.aot` to an `artifacts/*.hlo.txt` the Rust runtime loads.
Python never runs at training time.
"""

import jax.numpy as jnp

from .kernels.matmul import pallas_matmul


def _im2col(x, kh, kw, sh, sw):
    """Extract sliding patches: x [B,C,H,W] -> [B, C*KH*KW, OH*OW].

    Channel-major patch ordering (c, i, j) matches the row-major flatten
    of w [CO, CI, KH, KW] -> [CO, CI*KH*KW].
    """
    bsz, c, h, w = x.shape
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, :, i : i + sh * oh : sh, j : j + sw * ow : sw])
    # [B, C, KH*KW, OH, OW] with (i, j) minor -> matches w flatten order
    st = jnp.stack(cols, axis=2)
    return st.reshape(bsz, c * kh * kw, oh * ow), (oh, ow)


def _col2im(cols, x_shape, kh, kw, sh, sw):
    """Adjoint of `_im2col`: scatter-add patches back into the image."""
    bsz, c, h, w = x_shape
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    st = cols.reshape(bsz, c, kh * kw, oh, ow)
    dx = jnp.zeros(x_shape, dtype=cols.dtype)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            dx = dx.at[:, :, i : i + sh * oh : sh, j : j + sw * ow : sw].add(
                st[:, :, idx]
            )
            idx += 1
    return dx


def conv2d_fwd(x, w, b, stride=(1, 1)):
    """Valid convolution via im2col + Pallas GEMM.

    x [B,CI,H,W], w [CO,CI,KH,KW], b [CO] -> y [B,CO,OH,OW].
    """
    bsz, _, _, _ = x.shape
    co, _, kh, kw = w.shape
    patches, (oh, ow) = _im2col(x, kh, kw, *stride)
    # [CI*KH*KW, B*OH*OW]
    p2 = patches.transpose(1, 0, 2).reshape(patches.shape[1], bsz * oh * ow)
    w_mat = w.reshape(co, -1)
    y2 = pallas_matmul(w_mat, p2)  # [CO, B*OH*OW]
    y = y2.reshape(co, bsz, oh, ow).transpose(1, 0, 2, 3)
    return (y + b[None, :, None, None],)


def conv2d_bwd(x, w, dy, stride=(1, 1)):
    """Explicit conv VJP, hot paths on the Pallas GEMM.

    Returns (dx, dw, db).

    Perf note (EXPERIMENTS.md §Perf, iteration L2-1 — tried & reverted):
    computing dx as a full-correlation GEMM over a padded-dy im2col was
    4x *slower* than this scatter-based `_col2im` (the padded patch
    tensor is (k^2)x larger than dy and its materialisation dominated);
    the scatter path is the keeper.
    """
    bsz = x.shape[0]
    co, _, kh, kw = w.shape
    _, oh, ow = dy.shape[1], dy.shape[2], dy.shape[3]
    patches, _ = _im2col(x, kh, kw, *stride)
    p2 = patches.transpose(1, 0, 2).reshape(patches.shape[1], bsz * oh * ow)
    dy2 = dy.transpose(1, 0, 2, 3).reshape(co, bsz * oh * ow)
    # dw = dy2 @ patches^T
    dw = pallas_matmul(dy2, p2.T).reshape(w.shape)
    # dx = col2im(w_mat^T @ dy2)
    w_mat = w.reshape(co, -1)
    dcols2 = pallas_matmul(w_mat.T, dy2)  # [CI*KH*KW, B*OH*OW]
    dcols = dcols2.reshape(patches.shape[1], bsz, oh * ow).transpose(1, 0, 2)
    dx = _col2im(dcols, x.shape, kh, kw, *stride)
    db = jnp.sum(dy, axis=(0, 2, 3))
    return dx, dw, db


def affine_fwd(x, w, b):
    """y = x @ w.T + b via the Pallas GEMM."""
    return (pallas_matmul(x, w.T) + b[None, :],)


def affine_fwd_nobias(x, w):
    """y = x @ w.T — the variant for weight-grid cells without a bias
    shard (§4: bias lives on one P_fo x 1 subpartition only)."""
    return (pallas_matmul(x, w.T),)


def affine_bwd(x, w, dy):
    """(dx, dw, db) — three Pallas GEMMs and a reduction."""
    dx = pallas_matmul(dy, w)
    dw = pallas_matmul(dy.T, x)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db
