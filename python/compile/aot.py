"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.json.

Lowers every (function, shape) pair the Rust coordinator needs — the local
conv/affine kernels for both LeNet layouts (sequential and the paper's
4-worker decomposition) at the configured batch sizes — to HLO **text**.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which this image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifact names must match ``rust/src/runtime/mod.rs::names``.

Usage: ``cd python && python -m compile.aot --out ../artifacts [--batches 8,16,64]``
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Shape inventory (kept in sync with rust/src/models/lenet5.rs; the halo
# geometry makes every worker's local conv shape identical per layer).
# ---------------------------------------------------------------------------

# (ci, h_local, w_local, co, k, s): distributed (4-worker, 2x2 grid) and
# sequential LeNet conv layers. h/w are the trimmed+padded kernel inputs.
CONV_SHAPES = [
    # C1 distributed: 28x28 pad 2 over 2x2 -> local 18x18
    dict(ci=1, h=18, w=18, co=6, k=(5, 5), s=(1, 1)),
    # C3 distributed: 14x14 no pad over 2x2 -> local 9x9
    dict(ci=6, h=9, w=9, co=16, k=(5, 5), s=(1, 1)),
    # C1 sequential: pad materialised -> 32x32
    dict(ci=1, h=32, w=32, co=6, k=(5, 5), s=(1, 1)),
    # C3 sequential
    dict(ci=6, h=14, w=14, co=16, k=(5, 5), s=(1, 1)),
]

# (fi, fo): distributed affine cells and sequential affine layers.
AFFINE_SHAPES = [
    (200, 60),  # C5 cell
    (60, 42),  # F6 cell
    (42, 5),  # Output cell
    (400, 120),  # C5 sequential
    (120, 84),  # F6 sequential
    (84, 10),  # Output sequential
]


def to_hlo_text(fn, example_args):
    """Lower a jitted function to HLO text with a tuple return."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def build_registry(batches):
    """Yield (name, fn, example_args, num_outputs) for every artifact."""
    for b in batches:
        for cs in CONV_SHAPES:
            ci, h, w, co = cs["ci"], cs["h"], cs["w"], cs["co"]
            (kh, kw), (sh, sw) = cs["k"], cs["s"]
            x = spec(b, ci, h, w)
            wt = spec(co, ci, kh, kw)
            bias = spec(co)
            base = f"b{b}_ci{ci}_h{h}_w{w}_co{co}_k{kh}x{kw}_s{sh}x{sw}"
            yield (
                f"conv_fwd_{base}",
                lambda x, w_, b_, s=(sh, sw): model.conv2d_fwd(x, w_, b_, s),
                (x, wt, bias),
                1,
            )
            oh = (h - kh) // sh + 1
            ow = (w - kw) // sw + 1
            dy = spec(b, co, oh, ow)
            yield (
                f"conv_bwd_{base}",
                lambda x, w_, dy_, s=(sh, sw): model.conv2d_bwd(x, w_, dy_, s),
                (x, wt, dy),
                3,
            )
        for fi, fo in AFFINE_SHAPES:
            x = spec(b, fi)
            wt = spec(fo, fi)
            bias = spec(fo)
            dy = spec(b, fo)
            yield (f"affine_fwd_b{b}_fi{fi}_fo{fo}", model.affine_fwd, (x, wt, bias), 1)
            yield (
                f"affine_fwd_nobias_b{b}_fi{fi}_fo{fo}",
                model.affine_fwd_nobias,
                (x, wt),
                1,
            )
            yield (f"affine_bwd_b{b}_fi{fi}_fo{fo}", model.affine_bwd, (x, wt, dy), 3)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--batches",
        default="8,16,64",
        help="comma-separated batch sizes to specialise",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    batches = [int(s) for s in args.batches.split(",") if s]
    entries = []
    for name, fn, example_args, num_outputs in build_registry(batches):
        text = to_hlo_text(fn, example_args)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(a.shape) for a in example_args],
                "num_outputs": num_outputs,
            }
        )
        print(f"  lowered {name} ({len(text) / 1024:.0f} KiB)")
    manifest = {"entries": entries}
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(entries)} artifacts to {out}/ (manifest.json)")


if __name__ == "__main__":
    main()
