"""L1 correctness: the Pallas GEMM against the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple and degenerate
edges); explicit cases pin the LeNet shapes the artifacts specialise.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import pallas_matmul, vmem_footprint_bytes

RNG = np.random.default_rng(7)


def rand(m, n):
    return jnp.asarray(RNG.standard_normal((m, n)), dtype=jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
)
def test_matches_oracle_hypothesis(m, k, n):
    a, b = rand(m, k), rand(k, n)
    got = np.asarray(pallas_matmul(a, b))
    want = np.asarray(ref.matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (6, 25, 64 * 196),  # C1 local GEMM (dist, batch 64)
        (16, 150, 64 * 25),  # C3 local GEMM
        (64, 200, 60),  # C5 cell
        (64, 42, 5),  # Output cell
        (1, 1, 1),
        (128, 128, 128),  # exactly one MXU tile
        (129, 257, 130),  # just past tile boundaries
    ],
)
def test_lenet_shapes(m, k, n):
    a, b = rand(m, k), rand(k, n)
    got = np.asarray(pallas_matmul(a, b))
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_block_shape_invariance(bm, bk, bn):
    """The tiling must never change the numerics (same padded zeros)."""
    a, b = rand(50, 70), rand(70, 30)
    base = np.asarray(pallas_matmul(a, b))
    tiled = np.asarray(pallas_matmul(a, b, bm=bm, bk=bk, bn=bn))
    # different tilings re-associate the k-sum; only fp noise may differ
    np.testing.assert_allclose(base, tiled, rtol=1e-3, atol=1e-5)


def test_zero_and_identity():
    a = rand(17, 23)
    z = jnp.zeros((23, 9), jnp.float32)
    np.testing.assert_array_equal(np.asarray(pallas_matmul(a, z)), 0.0)
    eye = jnp.eye(17, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(pallas_matmul(eye, a)), np.asarray(a), rtol=1e-6
    )


def test_vmem_footprint_under_budget():
    """The largest tiles auto_blocks can pick must fit in a TPU core's
    ~16 MiB VMEM with double-buffered inputs: the DESIGN.md §Perf
    roofline argument."""
    assert vmem_footprint_bytes() <= 16 * 2 ** 20
    # and the MXU-shaped baseline is far smaller
    assert vmem_footprint_bytes(128, 128, 128) <= 512 * 1024
