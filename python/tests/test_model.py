"""L2 correctness: conv/affine forward + explicit VJPs against oracles.

The backward oracles come from jax.vjp of the lax-based reference, so
these tests certify that the hand-written matmul-based VJPs in model.py
are true adjoint computations of the forward functions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(11)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), dtype=jnp.float32)


# the shapes the artifacts specialise (distributed + sequential LeNet)
CONV_CASES = [
    (2, 1, 18, 18, 6, 5, 1),
    (2, 6, 9, 9, 16, 5, 1),
    (2, 1, 32, 32, 6, 5, 1),
    (2, 6, 14, 14, 16, 5, 1),
    (3, 2, 8, 10, 4, 3, 2),  # stride 2, rectangular
]


@pytest.mark.parametrize("b,ci,h,w,co,k,s", CONV_CASES)
def test_conv_forward_matches_lax(b, ci, h, w, co, k, s):
    x, wt, bias = rand(b, ci, h, w), rand(co, ci, k, k), rand(co)
    (got,) = model.conv2d_fwd(x, wt, bias, (s, s))
    want = ref.conv2d_ref(x, wt, bias, (s, s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,ci,h,w,co,k,s", CONV_CASES)
def test_conv_backward_matches_vjp(b, ci, h, w, co, k, s):
    x, wt = rand(b, ci, h, w), rand(co, ci, k, k)
    oh = (h - k) // s + 1
    ow = (w - k) // s + 1
    dy = rand(b, co, oh, ow)
    dx, dw, db = model.conv2d_bwd(x, wt, dy, (s, s))
    rdx, rdw, rdb = ref.conv2d_bwd_ref(x, wt, dy, (s, s))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    fi=st.integers(1, 64),
    fo=st.integers(1, 48),
)
def test_affine_forward_hypothesis(b, fi, fo):
    x, w, bias = rand(b, fi), rand(fo, fi), rand(fo)
    (got,) = model.affine_fwd(x, w, bias)
    want = ref.affine_ref(x, w, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    (got_nb,) = model.affine_fwd_nobias(x, w)
    np.testing.assert_allclose(
        np.asarray(got_nb), np.asarray(ref.affine_ref(x, w)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("b,fi,fo", [(64, 200, 60), (64, 60, 42), (64, 42, 5), (8, 400, 120)])
def test_affine_backward_matches_vjp(b, fi, fo):
    x, w, dy = rand(b, fi), rand(fo, fi), rand(b, fo)
    dx, dw, db = model.affine_bwd(x, w, dy)
    rdx, rdw, rdb = ref.affine_bwd_ref(x, w, dy)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-4, atol=1e-4)


def test_im2col_adjointness():
    """_col2im is the exact adjoint of _im2col: <im2col(x), y> == <x, col2im(y)>
    — the Eq. (13) test applied to the local patch operator."""
    x = rand(2, 3, 7, 8)
    cols, _ = model._im2col(x, 3, 3, 2, 2)
    y = rand(*cols.shape)
    lhs = float(jnp.sum(cols * y))
    rhs = float(jnp.sum(x * model._col2im(y, x.shape, 3, 3, 2, 2)))
    assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), 1.0)
