"""AOT path checks: registry completeness, HLO-text lowering, manifest
schema — the contract the Rust runtime (rust/src/runtime) consumes."""

import json

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_registry_covers_both_layouts():
    names = [name for name, *_ in aot.build_registry([8])]
    # distributed conv cells
    assert "conv_fwd_b8_ci1_h18_w18_co6_k5x5_s1x1" in names
    assert "conv_bwd_b8_ci6_h9_w9_co16_k5x5_s1x1" in names
    # sequential conv
    assert "conv_fwd_b8_ci1_h32_w32_co6_k5x5_s1x1" in names
    # affine cells, bias and nobias, fwd and bwd
    for n in (
        "affine_fwd_b8_fi200_fo60",
        "affine_fwd_nobias_b8_fi200_fo60",
        "affine_bwd_b8_fi200_fo60",
        "affine_fwd_b8_fi400_fo120",
    ):
        assert n in names, n
    # every entry unique
    assert len(names) == len(set(names))


def test_lowering_produces_hlo_text():
    x = jax.ShapeDtypeStruct((4, 42), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 42), jnp.float32)
    b = jax.ShapeDtypeStruct((5,), jnp.float32)
    text = aot.to_hlo_text(model.affine_fwd, (x, w, b))
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return (the Rust side unwraps with to_tuple)
    assert "tuple" in text.lower()


def test_manifest_end_to_end(tmp_path):
    """Run the real main() for one small batch and validate the manifest
    against what rust/src/runtime/mod.rs expects."""
    import sys
    from unittest import mock

    argv = ["aot", "--out", str(tmp_path), "--batches", "2"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["entries"], "empty manifest"
    for e in manifest["entries"]:
        assert set(e) == {"name", "file", "inputs", "num_outputs"}
        hlo = (tmp_path / e["file"]).read_text()
        assert hlo.startswith("HloModule"), e["name"]
        assert all(
            isinstance(s, list) and all(isinstance(d, int) for d in s)
            for s in e["inputs"]
        )


def test_conv_artifact_shapes_match_halo_geometry():
    """The hard-coded CONV_SHAPES must equal the Rust halo machinery's
    trimmed kernel-input sizes (C1: 18, C3: 9 per worker on the 2x2 grid;
    32 and 14 sequentially)."""

    def compute_len(n, p, k, s, pad, worker):
        m = (n + 2 * pad - k) // s + 1
        outs = [(m // p + (1 if i < m % p else 0)) for i in range(p)]
        ins = [(n // p + (1 if i < n % p else 0)) for i in range(p)]
        o_lo = sum(outs[:worker])
        o_hi = o_lo + outs[worker]
        need_lo = o_lo * s - pad
        need_hi = (o_hi - 1) * s - pad + k
        return need_hi - need_lo

    assert compute_len(28, 2, 5, 1, 2, 0) == 18
    assert compute_len(28, 2, 5, 1, 2, 1) == 18
    assert compute_len(14, 2, 5, 1, 0, 0) == 9
    assert compute_len(14, 2, 5, 1, 0, 1) == 9
    assert compute_len(28, 1, 5, 1, 2, 0) == 32
    assert compute_len(14, 1, 5, 1, 0, 0) == 14


import jax  # noqa: E402  (used by ShapeDtypeStruct above)
